"""Functional JAX model zoo for the AgileNN reproduction.

Every network is a pure function over an explicit parameter pytree so that
jax.grad / jax.jit / AOT lowering compose cleanly.  NHWC layout throughout.

Components (paper §3, §6, §7):
  * feature extractor — 2 convs, C=24 output channels (the on-device net),
    whose second conv is *linear* so the training-time 1x1 mapping layer can
    be folded into it exactly at export time (DESIGN.md §4),
  * mapping layer    — trainable 1x1 channel mix used only during training,
  * local NN         — GAP + dense over the top-k channels,
  * remote NN        — inverted-residual CNN over the remaining channels
    (MobileNetV2-family stand-in),
  * reference NN     — wide CNN head over the full feature map, pre-trained,
    frozen during joint training; target of XAI attribution,
  * baseline nets    — DeepCOD encoder/decoder, SPINN early-exit net,
    MCUNet-class full local net, edge-only remote net.

`macs()` helpers compute multiply-accumulate counts; the Rust device
simulator prices latency/energy from these numbers (exported in meta.json).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def conv2d(x, w, b, *, stride=1, padding="SAME"):
    """NHWC conv. w: (kh, kw, cin, cout)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def depthwise_conv2d(x, w, b, *, stride=1):
    """NHWC depthwise conv. w: (kh, kw, c, 1)."""
    c = x.shape[-1]
    y = jax.lax.conv_general_dilated(
        x,
        jnp.reshape(jnp.transpose(w, (0, 1, 3, 2)), (w.shape[0], w.shape[1], 1, c)),
        window_strides=(stride, stride),
        padding="SAME",
        feature_group_count=c,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def dense(x, w, b):
    return x @ w + b


def gap(x):
    """Global average pool NHWC -> NC."""
    return jnp.mean(x, axis=(1, 2))


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * np.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32)}


def _dense_init(key, din, dout):
    w = jax.random.normal(key, (din, dout), jnp.float32) * np.sqrt(2.0 / din)
    return {"w": w, "b": jnp.zeros((dout,), jnp.float32)}


def conv_macs(h, w, kh, kw, cin, cout, stride=1):
    return (h // stride) * (w // stride) * kh * kw * cin * cout


# ---------------------------------------------------------------------------
# feature extractor (on-device): conv s2 -> ReLU -> conv s2 (linear) -> map -> ReLU
# ---------------------------------------------------------------------------

EXTRACTOR_MID = 16
FEATURE_CHANNELS = 24  # C in the paper (§7: 24 output channels)
FEATURE_HW = 8  # 32 -> 16 -> 8 with two stride-2 convs


def init_extractor(key, *, mid=EXTRACTOR_MID, out=FEATURE_CHANNELS):
    k1, k2 = jax.random.split(key)
    return {
        "conv1": _conv_init(k1, 3, 3, 3, mid),
        "conv2": _conv_init(k2, 3, 3, mid, out),
    }


def init_mapping(key, *, out=FEATURE_CHANNELS):
    # identity-initialised 1x1 channel mix; Algorithm 1 re-initialises it as a
    # permutation (see train.permutation_mapping).
    del key
    return {"m": jnp.eye(out, dtype=jnp.float32)}


def extractor_apply(params, x, mapping=None, *, use_pallas=False):
    """x: (B,32,32,3) -> features (B,8,8,C), post-ReLU.

    The mapping layer (1x1 channel mix) sits *before* the final ReLU so it is
    linear w.r.t. conv2 and can be folded into conv2's weights at export time.
    """
    if use_pallas:
        from .kernels import extractor_conv as ek

        h = ek.conv2d_relu(x, params["conv1"]["w"], params["conv1"]["b"], stride=2)
        z = ek.conv2d_linear(h, params["conv2"]["w"], params["conv2"]["b"], stride=2)
    else:
        h = jax.nn.relu(conv2d(x, params["conv1"]["w"], params["conv1"]["b"], stride=2))
        z = conv2d(h, params["conv2"]["w"], params["conv2"]["b"], stride=2)
    if mapping is not None:
        z = jnp.einsum("bhwc,cd->bhwd", z, mapping["m"])
    return jax.nn.relu(z)


def fold_mapping(params, mapping):
    """Return extractor params with the 1x1 mapping folded into conv2 (exact)."""
    m = mapping["m"]
    return {
        "conv1": params["conv1"],
        "conv2": {
            "w": jnp.einsum("hwio,od->hwid", params["conv2"]["w"], m),
            "b": params["conv2"]["b"] @ m,
        },
    }


def extractor_macs(*, mid=EXTRACTOR_MID, out=FEATURE_CHANNELS):
    return conv_macs(32, 32, 3, 3, 3, mid, 2) + conv_macs(16, 16, 3, 3, mid, out, 2)


# ---------------------------------------------------------------------------
# local NN: GAP + dense over top-k channels
# ---------------------------------------------------------------------------


def init_local(key, k, num_classes):
    return {"fc": _dense_init(key, k, num_classes)}


def local_apply(params, feats_topk):
    """feats_topk: (B,8,8,k) -> logits (B,nc)."""
    return dense(gap(feats_topk), params["fc"]["w"], params["fc"]["b"])


def local_macs(k, num_classes):
    return FEATURE_HW * FEATURE_HW * k + k * num_classes  # GAP adds + dense


# ---------------------------------------------------------------------------
# remote NN: inverted-residual stack (MobileNetV2 stand-in, first conv removed)
# ---------------------------------------------------------------------------

REMOTE_WIDTHS = (48, 64, 96)
REMOTE_EXPAND = 3


def init_remote(key, cin, num_classes, *, widths=REMOTE_WIDTHS, expand=REMOTE_EXPAND):
    keys = jax.random.split(key, 3 * len(widths) + 2)
    blocks = []
    c = cin
    ki = 0
    for w in widths:
        e = c * expand
        blocks.append(
            {
                "expand": _conv_init(keys[ki], 1, 1, c, e),
                "dw": _conv_init(keys[ki + 1], 3, 3, 1, e),  # stored (3,3,1,e)
                "project": _conv_init(keys[ki + 2], 1, 1, e, w),
            }
        )
        ki += 3
        c = w
    head = _conv_init(keys[ki], 1, 1, c, 2 * c)
    fc = _dense_init(keys[ki + 1], 2 * c, num_classes)
    return {"blocks": blocks, "head": head, "fc": fc}


def remote_apply(params, feats):
    """feats: (B,8,8,cin) -> logits (B,nc). Strides: 1,2,1 over blocks."""
    x = feats
    strides = [1, 2, 1]
    for blk, s in zip(params["blocks"], strides):
        e = jax.nn.relu6(conv2d(x, blk["expand"]["w"], blk["expand"]["b"]))
        dw_w = jnp.transpose(blk["dw"]["w"], (0, 1, 3, 2))  # (3,3,e,1)
        d = jax.nn.relu6(depthwise_conv2d(e, dw_w, blk["dw"]["b"], stride=s))
        p = conv2d(d, blk["project"]["w"], blk["project"]["b"])
        if p.shape == x.shape:
            p = p + x
        x = p
    h = jax.nn.relu(conv2d(x, params["head"]["w"], params["head"]["b"]))
    return dense(gap(h), params["fc"]["w"], params["fc"]["b"])


def remote_macs(cin, num_classes, *, widths=REMOTE_WIDTHS, expand=REMOTE_EXPAND):
    total, c, hw = 0, cin, FEATURE_HW
    for w, s in zip(widths, [1, 2, 1]):
        e = c * expand
        total += conv_macs(hw, hw, 1, 1, c, e)
        total += conv_macs(hw, hw, 3, 3, 1, e, s)  # depthwise
        hw //= s
        total += conv_macs(hw, hw, 1, 1, e, w)
        c = w
    total += conv_macs(hw, hw, 1, 1, c, 2 * c)
    total += 2 * c * num_classes
    return total


# ---------------------------------------------------------------------------
# reference NN head (XAI target): wide CNN over the full feature map, frozen
# ---------------------------------------------------------------------------

REFERENCE_WIDTH = 96


def init_reference(key, cin, num_classes, *, width=REFERENCE_WIDTH):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv1": _conv_init(k1, 3, 3, cin, width),
        "conv2": _conv_init(k2, 3, 3, width, width),
        "fc": _dense_init(k3, width, num_classes),
    }


def reference_apply(params, feats):
    x = jax.nn.relu(conv2d(feats, params["conv1"]["w"], params["conv1"]["b"]))
    x = jax.nn.relu(conv2d(x, params["conv2"]["w"], params["conv2"]["b"], stride=2))
    return dense(gap(x), params["fc"]["w"], params["fc"]["b"])


# ---------------------------------------------------------------------------
# DeepCOD baseline: learned encoder on-device, decoder + classifier remote
# ---------------------------------------------------------------------------

DEEPCOD_CODE_CHANNELS = 12


def init_deepcod(key, num_classes, *, code=DEEPCOD_CODE_CHANNELS):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        # encoder (on device): heavier than AgileNN's extractor, as in §2.1
        "enc1": _conv_init(k1, 3, 3, 3, 32),
        "enc2": _conv_init(k2, 3, 3, 32, 32),
        "enc3": _conv_init(k3, 3, 3, 32, code),
        # decoder + classifier (remote)
        "dec1": _conv_init(k4, 3, 3, code, 48),
        "remote": init_remote(k5, 48, num_classes),
    }


def deepcod_encode(params, x):
    """(B,32,32,3) -> code (B,8,8,code). The transmitted representation."""
    h = jax.nn.relu(conv2d(x, params["enc1"]["w"], params["enc1"]["b"], stride=2))
    h = jax.nn.relu(conv2d(h, params["enc2"]["w"], params["enc2"]["b"]))
    return conv2d(h, params["enc3"]["w"], params["enc3"]["b"], stride=2)


def deepcod_decode(params, code):
    h = jax.nn.relu(conv2d(code, params["dec1"]["w"], params["dec1"]["b"]))
    return remote_apply(params["remote"], h)


def deepcod_encoder_macs(*, code=DEEPCOD_CODE_CHANNELS):
    return (
        conv_macs(32, 32, 3, 3, 3, 32, 2)
        + conv_macs(16, 16, 3, 3, 32, 32)
        + conv_macs(16, 16, 3, 3, 32, code, 2)
    )


# ---------------------------------------------------------------------------
# SPINN baseline: partitioned net with an on-device early exit
# ---------------------------------------------------------------------------


def init_spinn(key, num_classes):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # device part: 3 convs (heavier than AgileNN, per Fig 16's local times)
        "conv1": _conv_init(k1, 3, 3, 3, 24),
        "conv2": _conv_init(k2, 3, 3, 24, 32),
        "exit_fc": _dense_init(k3, 32, num_classes),  # early-exit head
        "remote": init_remote(k4, 32, num_classes),
    }


def spinn_device(params, x):
    """-> (features (B,8,8,32), early-exit logits (B,nc))."""
    h = jax.nn.relu(conv2d(x, params["conv1"]["w"], params["conv1"]["b"], stride=2))
    h = jax.nn.relu(conv2d(h, params["conv2"]["w"], params["conv2"]["b"], stride=2))
    exit_logits = dense(gap(h), params["exit_fc"]["w"], params["exit_fc"]["b"])
    return h, exit_logits


def spinn_remote(params, feats):
    return remote_apply(params["remote"], feats)


def spinn_device_macs(num_classes):
    return (
        conv_macs(32, 32, 3, 3, 3, 24, 2)
        + conv_macs(16, 16, 3, 3, 24, 32, 2)
        + 32 * num_classes
    )


# ---------------------------------------------------------------------------
# MCUNet baseline: full local inference, NAS-style budgeted CNN
# ---------------------------------------------------------------------------


def init_mcunet(key, num_classes):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "conv1": _conv_init(k1, 3, 3, 3, 16),
        "conv2": _conv_init(k2, 3, 3, 16, 32),
        "conv3": _conv_init(k3, 3, 3, 32, 64),
        "conv4": _conv_init(k4, 3, 3, 64, 96),
        "fc": _dense_init(k5, 96, num_classes),
    }


def mcunet_apply(params, x):
    h = jax.nn.relu(conv2d(x, params["conv1"]["w"], params["conv1"]["b"], stride=2))
    h = jax.nn.relu(conv2d(h, params["conv2"]["w"], params["conv2"]["b"], stride=2))
    h = jax.nn.relu(conv2d(h, params["conv3"]["w"], params["conv3"]["b"], stride=2))
    h = jax.nn.relu(conv2d(h, params["conv4"]["w"], params["conv4"]["b"]))
    return dense(gap(h), params["fc"]["w"], params["fc"]["b"])


def mcunet_macs(num_classes):
    return (
        conv_macs(32, 32, 3, 3, 3, 16, 2)
        + conv_macs(16, 16, 3, 3, 16, 32, 2)
        + conv_macs(8, 8, 3, 3, 32, 64, 2)
        + conv_macs(4, 4, 3, 3, 64, 96)
        + 96 * num_classes
    )


# ---------------------------------------------------------------------------
# edge-only baseline: full remote model over the (compressed) raw image
# ---------------------------------------------------------------------------


def init_edgeonly(key, num_classes):
    k1, k2 = jax.random.split(key)
    return {"stem": _conv_init(k1, 3, 3, 3, 24), "remote": init_remote(k2, 24, num_classes)}


def edgeonly_apply(params, x):
    h = jax.nn.relu(conv2d(x, params["stem"]["w"], params["stem"]["b"], stride=4))
    return remote_apply(params["remote"], h)


# ---------------------------------------------------------------------------
# parameter accounting
# ---------------------------------------------------------------------------


def param_count(tree) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(tree)))


def param_bytes(tree, *, dtype_bytes=1) -> int:
    """Model size on flash; device models ship int8 (dtype_bytes=1)."""
    return param_count(tree) * dtype_bytes
