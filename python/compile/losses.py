"""AgileNN training losses (paper Eq. 1, Eq. 2, §4.2) and the alpha combiner."""

from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_LAMBDA = 0.3  # paper §4.2: moderate lambda in [0.2, 0.4]
DEFAULT_T = 6.0  # paper §3.3: moderate T in [4, 8]


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def disorder_loss(imp, k, *, sample_mask=None):
    """Eq. (1): max(0, max(I2) - min(I1)) per sample, averaged.

    I1 = importances of the first k channels, I2 = the rest.  Non-zero only
    when some less-important-slot channel outranks a top-k-slot channel.
    """
    viol = jax.nn.relu(jnp.max(imp[:, k:], axis=-1) - jnp.min(imp[:, :k], axis=-1))
    return _masked_mean(viol, sample_mask)


def skewness_loss(imp, k, rho, *, sample_mask=None):
    """Eq. (2): max(0, rho - |I1|_1) per sample, averaged."""
    deficit = jax.nn.relu(rho - jnp.sum(imp[:, :k], axis=-1))
    return _masked_mean(deficit, sample_mask)


def descending_sort_loss(imp, *, sample_mask=None):
    """The strawman L_descent = ||I - sort_desc(I)||^2 (§4.1, Fig 9)."""
    target = -jnp.sort(-imp, axis=-1)
    per_sample = jnp.sum((imp - target) ** 2, axis=-1)
    return _masked_mean(per_sample, sample_mask)


def _masked_mean(x, mask):
    if mask is None:
        return jnp.mean(x)
    # mask: 1.0 where the reference NN predicted correctly (§3.1) — XAI
    # evaluations from wrong reference outputs are discarded.
    return jnp.sum(x * mask) / (jnp.sum(mask) + 1e-9)


def alpha_of(w, *, T=DEFAULT_T):
    """alpha(w; T) = sigmoid(w / T) — the soft-constrained combiner weight."""
    return jax.nn.sigmoid(w / T)


def combine_predictions(local_logits, remote_logits, alpha):
    """Final output: alpha * local + (1 - alpha) * remote (point-to-point)."""
    return alpha * local_logits + (1.0 - alpha) * remote_logits


def combined_loss(pred_loss, skew_loss, dis_loss, *, lam=DEFAULT_LAMBDA):
    """L = lambda * L_pred + (1 - lambda) * (L_skew + L_dis)  (§4.2)."""
    return lam * pred_loss + (1.0 - lam) * (skew_loss + dis_loss)
