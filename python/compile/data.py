"""Synthetic structured datasets standing in for CIFAR-10/100, SVHN, ImageNet-200.

The environment has no network access, so the paper's natural-image datasets
are substituted with class-conditional procedural images (see DESIGN.md §3).
Each class owns a smooth low-frequency "prototype" texture plus a class-coded
geometric glyph; samples perturb the prototype with translation, contrast
jitter and pixel noise.  The generator is deterministic given (name, split).

Design goals that mirror the real datasets' role in the paper:
  * classes are separable by a strong (reference) network but not trivially,
  * per-dataset difficulty ordering matches the paper
    (svhns < cifar10s < cifar100s < imagenet200s),
  * feature-importance skewness of a *naively* trained extractor is low
    (Fig 4), leaving headroom for skewness manipulation to act on.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

IMG = 32  # paper scales to 96x96; we use 32x32 to keep build-time training cheap


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_classes: int
    train_size: int
    test_size: int
    noise: float          # pixel-noise sigma -> difficulty
    jitter: int           # max |translation| in pixels
    proto_freqs: int      # number of Fourier components per prototype
    seed: int


SPECS: dict[str, DatasetSpec] = {
    # difficulty ordering mirrors the paper's accuracy ordering
    "svhns": DatasetSpec("svhns", 10, 6144, 1024, 0.12, 2, 3, 101),
    "cifar10s": DatasetSpec("cifar10s", 10, 6144, 1024, 0.22, 3, 4, 102),
    "cifar100s": DatasetSpec("cifar100s", 100, 8192, 1024, 0.28, 3, 5, 103),
    "imagenet200s": DatasetSpec("imagenet200s", 200, 10240, 1024, 0.32, 4, 6, 104),
}


def _class_prototype(rng: np.random.Generator, freqs: int) -> np.ndarray:
    """Smooth low-frequency RGB texture unique to a class."""
    yy, xx = np.meshgrid(np.linspace(0, 1, IMG), np.linspace(0, 1, IMG), indexing="ij")
    img = np.zeros((IMG, IMG, 3), dtype=np.float64)
    for _ in range(freqs):
        fx, fy = rng.uniform(0.5, 3.5, size=2)
        phase = rng.uniform(0, 2 * np.pi, size=3)
        amp = rng.uniform(0.25, 0.9, size=3)
        wave = np.sin(2 * np.pi * (fx * xx + fy * yy)[..., None] + phase) * amp
        img += wave
    img = (img - img.min()) / (img.max() - img.min() + 1e-9)
    return img.astype(np.float32)


def _class_glyph(rng: np.random.Generator) -> np.ndarray:
    """Class-coded geometric mark: a bright bar/blob at a class-specific spot."""
    mask = np.zeros((IMG, IMG, 1), dtype=np.float32)
    cy, cx = rng.integers(6, IMG - 6, size=2)
    h, w = rng.integers(3, 8, size=2)
    mask[cy - h // 2 : cy + (h + 1) // 2, cx - w // 2 : cx + (w + 1) // 2] = 1.0
    color = rng.uniform(0.4, 1.0, size=3).astype(np.float32)
    return mask * color[None, None, :]


@lru_cache(maxsize=None)
def _prototypes(name: str) -> tuple[np.ndarray, np.ndarray]:
    spec = SPECS[name]
    rng = np.random.default_rng(spec.seed)
    protos = np.stack([_class_prototype(rng, spec.proto_freqs) for _ in range(spec.num_classes)])
    glyphs = np.stack([_class_glyph(rng) for _ in range(spec.num_classes)])
    return protos, glyphs


def _render(spec: DatasetSpec, protos, glyphs, labels, rng) -> np.ndarray:
    n = len(labels)
    imgs = protos[labels].copy()  # (n, IMG, IMG, 3)
    # blend in the class glyph
    imgs = 0.65 * imgs + 0.35 * glyphs[labels]
    # random translation per sample (roll is cheap and wraps, fine for textures)
    for i in range(n):
        dy, dx = rng.integers(-spec.jitter, spec.jitter + 1, size=2)
        imgs[i] = np.roll(imgs[i], (dy, dx), axis=(0, 1))
    # contrast / brightness jitter
    gain = rng.uniform(0.8, 1.2, size=(n, 1, 1, 1)).astype(np.float32)
    bias = rng.uniform(-0.08, 0.08, size=(n, 1, 1, 1)).astype(np.float32)
    imgs = imgs * gain + bias
    # pixel noise controls difficulty
    imgs += rng.normal(0.0, spec.noise, size=imgs.shape).astype(np.float32)
    return np.clip(imgs, 0.0, 1.0).astype(np.float32)


def load(name: str, split: str = "train") -> tuple[np.ndarray, np.ndarray]:
    """Return (images[N,32,32,3] float32 in [0,1], labels[N] int32)."""
    spec = SPECS[name]
    protos, glyphs = _prototypes(name)
    if split == "train":
        size, seed = spec.train_size, spec.seed * 7 + 1
    elif split == "test":
        size, seed = spec.test_size, spec.seed * 7 + 2
    else:
        raise ValueError(f"unknown split {split!r}")
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, spec.num_classes, size=size).astype(np.int32)
    imgs = _render(spec, protos, glyphs, labels, rng)
    return imgs, labels


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, *, seed: int, epochs: int = 1):
    """Yield shuffled (x, y) minibatches; drops the ragged tail."""
    rng = np.random.default_rng(seed)
    n = len(x)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i : i + batch_size]
            yield x[idx], y[idx]
