"""AOT export: trains every scheme and lowers all serving-path graphs to HLO
text for the Rust coordinator (build-time only; never on the request path).

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the `xla`
crate's backend) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per dataset, artifacts/<dataset>/ receives:
  agile_device_b{1,8}.hlo.txt   x -> (local_logits, remote_feats)  [Pallas conv]
  agile_remote_b{1,2,4,8}.hlo.txt   remote_feats -> logits
  deepcod_device_b{1,8}.hlo.txt x -> code
  deepcod_remote_b{1,2,4,8}.hlo.txt code -> logits
  spinn_device_b{1,8}.hlo.txt   x -> (feats, exit_logits)
  spinn_remote_b{1,2,4,8}.hlo.txt   feats -> logits
  mcunet_local_b{1,8}.hlo.txt   x -> logits
  edge_remote_b{1,4}.hlo.txt    x -> logits
  meta.json                     alpha, k, rho, codebooks, MACs, bytes, accs
  test.bin                      test images + labels (Rust workload loader)

Usage: python -m compile.aot --out ../artifacts [--datasets a,b] [--quick]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, models, quantize, train, xai

REMOTE_BATCHES = (1, 2, 4, 8)
DEVICE_BATCHES = (1, 8)
CODEBOOK_BITS = (1, 2, 3, 4, 5, 6)
TEST_BIN_MAGIC = 0x41474C45  # "AGLE"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default HLO printer
    # elides big weight constants as `{...}`, which the text parser on the
    # Rust side silently reads back as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def export_fn(fn, example_args, path: pathlib.Path) -> int:
    """Lower `fn` at `example_args` shapes and write HLO text. Returns bytes."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path.write_text(text)
    return len(text)


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# ---------------------------------------------------------------------------
# serving graphs (closures over trained params; params get constant-folded
# into the HLO so the artifact is self-contained)
# ---------------------------------------------------------------------------


def agile_device_fn(res: train.TrainResult, *, use_pallas=True):
    k = res.cfg.k

    def fn(x):
        feats = models.extractor_apply(res.ext, x, use_pallas=use_pallas)
        local_logits = models.local_apply(res.local, feats[..., :k])
        return local_logits, feats[..., k:]

    return fn


def agile_remote_fn(res: train.TrainResult):
    def fn(feats):
        return (models.remote_apply(res.remote, feats),)

    return fn


def write_test_bin(path: pathlib.Path, x: np.ndarray, y: np.ndarray) -> None:
    """Header: magic, n, h, w, c (LE u32); then f32 images; then i32 labels."""
    n, h, w, c = x.shape
    with open(path, "wb") as f:
        f.write(struct.pack("<IIIII", TEST_BIN_MAGIC, n, h, w, c))
        f.write(np.ascontiguousarray(x, dtype="<f4").tobytes())
        f.write(np.ascontiguousarray(y, dtype="<i4").tobytes())


# ---------------------------------------------------------------------------
# per-dataset pipeline
# ---------------------------------------------------------------------------


def build_dataset(name: str, out_dir: pathlib.Path, *, quick: bool, log) -> dict:
    t0 = time.time()
    spec = data.SPECS[name]
    ddir = out_dir / name
    ddir.mkdir(parents=True, exist_ok=True)

    if quick:
        cfg = train.AgileConfig(dataset=name, pre_steps=80, joint_steps=80, ig_steps=2)
        bl_steps = 80
        test_n = 256
    else:
        cfg = train.AgileConfig(dataset=name, pre_steps=250, joint_steps=350, ig_steps=4)
        bl_steps = 350
        test_n = 512

    x_train, y_train = data.load(name, "train")
    x_test, y_test = data.load(name, "test")

    log(f"[{name}] training AgileNN (pre={cfg.pre_steps}, joint={cfg.joint_steps})")
    res = train.train_agilenn(cfg, log_every=0)

    log(f"[{name}] training baselines ({bl_steps} steps each)")
    deepcod, dc_hist = train.train_deepcod(cfg, x_train, y_train, steps=bl_steps)
    spinn, sp_hist = train.train_spinn(cfg, x_train, y_train, steps=bl_steps)
    mcunet, mc_hist = train.train_mcunet(cfg, x_train, y_train, steps=bl_steps)
    edge, eo_hist = train.train_edgeonly(cfg, x_train, y_train, steps=bl_steps)

    # ---- export HLO ----
    log(f"[{name}] exporting HLO artifacts")
    k, c, nc = cfg.k, models.FEATURE_CHANNELS, spec.num_classes
    hw = models.FEATURE_HW
    for b in DEVICE_BATCHES:
        export_fn(agile_device_fn(res), (_spec((b, 32, 32, 3)),),
                  ddir / f"agile_device_b{b}.hlo.txt")
        export_fn(lambda x: (models.deepcod_encode(deepcod, x),),
                  (_spec((b, 32, 32, 3)),), ddir / f"deepcod_device_b{b}.hlo.txt")
        export_fn(lambda x: models.spinn_device(spinn, x),
                  (_spec((b, 32, 32, 3)),), ddir / f"spinn_device_b{b}.hlo.txt")
        export_fn(lambda x: (models.mcunet_apply(mcunet, x),),
                  (_spec((b, 32, 32, 3)),), ddir / f"mcunet_local_b{b}.hlo.txt")
    for b in REMOTE_BATCHES:
        export_fn(agile_remote_fn(res), (_spec((b, hw, hw, c - k)),),
                  ddir / f"agile_remote_b{b}.hlo.txt")
        export_fn(lambda z: (models.deepcod_decode(deepcod, z),),
                  (_spec((b, hw, hw, models.DEEPCOD_CODE_CHANNELS)),),
                  ddir / f"deepcod_remote_b{b}.hlo.txt")
        export_fn(lambda f: (models.spinn_remote(spinn, f),),
                  (_spec((b, hw, hw, 32)),), ddir / f"spinn_remote_b{b}.hlo.txt")
    for b in (1, 4):
        export_fn(lambda x: (models.edgeonly_apply(edge, x),),
                  (_spec((b, 32, 32, 3)),), ddir / f"edge_remote_b{b}.hlo.txt")

    # ---- codebooks over the transmitted-feature distribution ----
    feats_fn = jax.jit(lambda xb: models.extractor_apply(res.ext, xb))
    sample_feats = np.asarray(feats_fn(jnp.asarray(x_train[:512])))
    remote_feats = sample_feats[..., k:]
    codebooks = {str(b): quantize.fit_codebook(remote_feats, b).tolist() for b in CODEBOOK_BITS}
    code_entropy = {
        b: quantize.code_entropy_bits(quantize.quantize(remote_feats,
                                                        np.asarray(codebooks[str(b)], np.float32)))
        for b in CODEBOOK_BITS
    }
    # DeepCOD transmits its learned code; fit codebooks for it too
    dc_code = np.asarray(jax.jit(lambda xb: models.deepcod_encode(deepcod, xb))(
        jnp.asarray(x_train[:512])))
    dc_codebooks = {str(b): quantize.fit_codebook(dc_code, b).tolist() for b in CODEBOOK_BITS}
    # SPINN transmits raw intermediate features
    sp_feats = np.asarray(jax.jit(lambda xb: models.spinn_device(spinn, xb)[0])(
        jnp.asarray(x_train[:512])))
    sp_codebooks = {str(b): quantize.fit_codebook(sp_feats, b).tolist() for b in CODEBOOK_BITS}

    # ---- accuracies (python cross-check; Rust re-measures end-to-end) ----
    log(f"[{name}] measuring accuracies")
    xt, yt = x_test[:test_n], y_test[:test_n]
    acc_agile = train.eval_agilenn(res, xt, yt)
    acc_agile_q4 = train.eval_agilenn(
        res, xt, yt, quant_codebook=np.asarray(codebooks["4"], np.float32))
    acc_agile_local = train.eval_agilenn(res, xt, yt, alpha=1.0)
    acc_deepcod = train.eval_simple(
        lambda p, x: models.deepcod_decode(p, models.deepcod_encode(p, x)), deepcod, xt, yt,
        use_jit=False)
    acc_spinn = train.eval_simple(
        lambda p, x: models.spinn_remote(p, models.spinn_device(p, x)[0]), spinn, xt, yt,
        use_jit=False)
    acc_mcunet = train.eval_simple(models.mcunet_apply, mcunet, xt, yt, use_jit=False)
    acc_edge = train.eval_simple(models.edgeonly_apply, edge, xt, yt, use_jit=False)

    # SPINN early-exit calibration: max-softmax confidence on train subset
    sp_dev = jax.jit(lambda xb: models.spinn_device(spinn, xb))
    _, exit_logits = sp_dev(jnp.asarray(x_train[:1024]))
    conf = np.asarray(jax.nn.softmax(exit_logits).max(axis=-1))
    exit_pred = np.asarray(exit_logits.argmax(axis=-1))
    thr = 0.9
    exit_rate = float((conf >= thr).mean())
    exit_acc = float((exit_pred[conf >= thr] == y_train[:1024][conf >= thr]).mean()) \
        if exit_rate > 0 else 0.0

    # ---- importance statistics (Fig 4 / Fig 21 inputs) ----
    imps = train.collect_importances(res, xt, yt, max_samples=min(512, test_n))
    nat_skew = np.sort(np.asarray(xai.natural_skewness(jnp.asarray(imps), k)))
    ach_skew = np.asarray(xai.achieved_skewness(jnp.asarray(imps), k))
    dis_rate = float(np.asarray(xai.disorder_rate(jnp.asarray(imps), k)))

    # ---- test set for Rust ----
    write_test_bin(ddir / "test.bin", x_test[:test_n], y_test[:test_n])

    meta = {
        "dataset": name,
        "num_classes": nc,
        "image": [32, 32, 3],
        "feature": [hw, hw, c],
        "k": k,
        "rho": cfg.rho,
        "lambda": cfg.lam,
        "T": cfg.T,
        "alpha": res.alpha,
        "w_alpha": res.w_alpha,
        "xai_tool": cfg.xai_tool,
        "selected_channels": res.selected_channels,
        "channel_likelihood": res.channel_likelihood,
        "codebooks": codebooks,
        "code_entropy_bits": {str(b): e for b, e in code_entropy.items()},
        "deepcod_codebooks": dc_codebooks,
        "spinn_codebooks": sp_codebooks,
        "macs": {
            "agile_device": models.extractor_macs() + models.local_macs(k, nc),
            "agile_extractor": models.extractor_macs(),
            "agile_local": models.local_macs(k, nc),
            "agile_remote": models.remote_macs(c - k, nc),
            "deepcod_device": models.deepcod_encoder_macs(),
            "spinn_device": models.spinn_device_macs(nc),
            "mcunet_local": models.mcunet_macs(nc),
        },
        "param_bytes_int8": {
            "agile_device": models.param_bytes({"e": res.ext, "l": res.local}),
            "deepcod_device": models.param_bytes(
                {k2: deepcod[k2] for k2 in ("enc1", "enc2", "enc3")}),
            "spinn_device": models.param_bytes(
                {k2: spinn[k2] for k2 in ("conv1", "conv2", "exit_fc")}),
            "mcunet_local": models.param_bytes(mcunet),
        },
        "tx_elements": {
            "agile": hw * hw * (c - k),
            "deepcod": hw * hw * models.DEEPCOD_CODE_CHANNELS,
            "spinn": hw * hw * 32,
            "edge_raw_bytes": 32 * 32 * 3,
        },
        "accuracy": {
            "agile": acc_agile,
            "agile_quant4": acc_agile_q4,
            "agile_local_only": acc_agile_local,
            "deepcod": acc_deepcod,
            "spinn_final": acc_spinn,
            "mcunet": acc_mcunet,
            "edge_only": acc_edge,
        },
        "spinn_exit": {"threshold": thr, "rate": exit_rate, "accuracy": exit_acc},
        "importance": {
            "natural_skewness_quantiles": {
                "p10": float(nat_skew[int(0.10 * len(nat_skew))]),
                "p50": float(nat_skew[int(0.50 * len(nat_skew))]),
                "p90": float(nat_skew[int(0.90 * len(nat_skew))]),
            },
            "achieved_skewness_mean": float(ach_skew.mean()),
            "disorder_rate": dis_rate,
            "mean_importance_per_channel": imps.mean(axis=0).tolist(),
        },
        "training": {
            "pre_steps": cfg.pre_steps,
            "joint_steps": cfg.joint_steps,
            "final_train_acc": float(np.mean(res.history["acc"][-25:])),
            "final_skew": float(np.mean(res.history["skew"][-25:])),
            "loss_curve": res.history["loss"][::5],
            "acc_curve": res.history["acc"][::5],
            "baseline_loss_final": {
                "deepcod": float(np.mean(dc_hist[-25:])),
                "spinn": float(np.mean(sp_hist[-25:])),
                "mcunet": float(np.mean(mc_hist[-25:])),
                "edge_only": float(np.mean(eo_hist[-25:])),
            },
        },
        "build_seconds": round(time.time() - t0, 1),
    }
    (ddir / "meta.json").write_text(json.dumps(meta, indent=1))
    log(f"[{name}] done in {meta['build_seconds']}s: "
        f"agile={acc_agile:.3f} deepcod={acc_deepcod:.3f} spinn={acc_spinn:.3f} "
        f"mcunet={acc_mcunet:.3f} edge={acc_edge:.3f} alpha={res.alpha:.2f}")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--datasets", default="svhns,cifar10s,cifar100s,imagenet200s")
    ap.add_argument("--quick", action="store_true", help="tiny training runs (CI)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = [n.strip() for n in args.datasets.split(",") if n.strip()]
    manifest = {"datasets": [], "quick": args.quick}
    for name in names:
        build_dataset(name, out_dir, quick=args.quick, log=print)
        manifest["datasets"].append(name)
        # incremental: a partially-built tree is already servable
        (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"manifest: {manifest}")


if __name__ == "__main__":
    main()
