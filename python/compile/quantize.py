"""Learned scalar quantization for the transmitted (less-important) features.

Paper §6: "we first adopt learning-based quantization [4] and then apply
standard LZW compression".  We fit a k-means codebook (Lloyd's algorithm) per
bit-width over the remote-feature distribution of the training set — the
learned, non-uniform analogue of [4]'s soft-to-hard VQ — and export the
codebooks in meta.json.  The Rust coordinator performs the actual
quantize -> LZW -> transmit path at serving time; this module is also used at
build time to measure accuracy-vs-rate (Fig 17/21) and to inject quantization
noise during joint training.
"""

from __future__ import annotations

import numpy as np


def fit_codebook(values: np.ndarray, bits: int, *, iters: int = 25, seed: int = 0) -> np.ndarray:
    """Lloyd k-means over scalar feature values -> sorted codebook (2^bits,)."""
    flat = np.asarray(values, dtype=np.float32).ravel()
    if flat.size > 200_000:  # subsample for speed; distribution is what matters
        rng = np.random.default_rng(seed)
        flat = rng.choice(flat, 200_000, replace=False)
    n = 1 << bits
    # init at quantiles — robust for the heavily zero-skewed feature dists
    code = np.quantile(flat, (np.arange(n) + 0.5) / n).astype(np.float32)
    for _ in range(iters):
        edges = (code[1:] + code[:-1]) / 2
        idx = np.searchsorted(edges, flat)
        sums = np.bincount(idx, weights=flat, minlength=n)
        cnts = np.bincount(idx, minlength=n)
        nonempty = cnts > 0
        new = code.copy()
        new[nonempty] = (sums[nonempty] / cnts[nonempty]).astype(np.float32)
        if np.allclose(new, code, atol=1e-7):
            code = new
            break
        code = new
    return np.sort(code.astype(np.float32))


def quantize(values: np.ndarray, codebook: np.ndarray) -> np.ndarray:
    """-> uint8/uint16 code indices (nearest codeword)."""
    edges = (codebook[1:] + codebook[:-1]) / 2
    idx = np.searchsorted(edges, values)
    return idx.astype(np.uint16 if len(codebook) > 256 else np.uint8)


def dequantize(indices: np.ndarray, codebook: np.ndarray) -> np.ndarray:
    return codebook[indices.astype(np.int64)].astype(np.float32)


def roundtrip(values: np.ndarray, codebook: np.ndarray) -> np.ndarray:
    return dequantize(quantize(values, codebook), codebook)


def quantization_mse(values: np.ndarray, codebook: np.ndarray) -> float:
    return float(np.mean((roundtrip(values, codebook) - values) ** 2))


def code_entropy_bits(indices: np.ndarray) -> float:
    """Empirical entropy of the code stream — lower bound on LZW output bits
    per symbol; used for the compression-rate estimates in meta.json."""
    _, counts = np.unique(indices, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())
