"""XAI attribution tools (paper §2.2, §7.7): Integrated Gradients + Gradient
Saliency over the *reference NN*, producing per-channel feature importance.

The reference NN is pre-trained and frozen; attribution asks "how much does
feature channel c of this sample contribute to the reference NN's confidence
in the true class?".  Importance is L1-normalised per sample so skewness
thresholds (rho) are scale-free.

Both tools are differentiable w.r.t. the features, which is what lets the
disorder/skewness losses push gradients back into the feature extractor
(grad-of-grad through the reference NN; it is small enough for this to be
cheap at build time).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import models

IG_STEPS = 8  # paper: 20-100 for reporting; 8 suffices for the training signal


def _target_logit(ref_params, feats, labels):
    logits = models.reference_apply(ref_params, feats)
    return jnp.sum(jnp.take_along_axis(logits, labels[:, None], axis=1))


def _feat_grad(ref_params, feats, labels):
    return jax.grad(_target_logit, argnums=1)(ref_params, feats, labels)


def ig_grads(ref_params, feats, labels, *, steps=IG_STEPS):
    """Gradients at `steps` linear interpolation points (zero baseline).

    Returns (steps, B, H, W, C) — the input to the Pallas IG kernel.
    """
    # midpoint rule over the path integral: alpha = (i + 0.5) / steps
    alphas = (jnp.arange(steps, dtype=jnp.float32) + 0.5) / steps

    def one(a):
        return _feat_grad(ref_params, a * feats, labels)

    return jax.vmap(one)(alphas)


def ig_importance(ref_params, feats, labels, *, steps=IG_STEPS, use_pallas=False):
    """Integrated-Gradients per-channel importance, (B, C), L1-normalised."""
    grads = ig_grads(ref_params, feats, labels, steps=steps)
    if use_pallas:
        from .kernels import ig as ig_kernel

        return ig_kernel.ig_channel_importance(feats, grads)
    from .kernels import ref as kref

    return kref.ig_channel_importance_ref(feats, grads)


def gs_importance(ref_params, feats, labels):
    """Gradient-Saliency importance (single gradient), (B, C)."""
    g = _feat_grad(ref_params, feats, labels)
    imp = jnp.sum(jnp.abs(feats * g), axis=(1, 2))
    return imp / (jnp.sum(imp, axis=-1, keepdims=True) + 1e-9)


def importance_fn(name: str):
    if name == "ig":
        return partial(ig_importance, steps=IG_STEPS)
    if name == "gs":
        return gs_importance
    raise ValueError(f"unknown XAI tool {name!r}")


# ---------------------------------------------------------------------------
# skewness metrics (paper §2.3, Fig 4 / Fig 21)
# ---------------------------------------------------------------------------


def natural_skewness(imp, k):
    """Normalised importance of the top-k channels after sorting, (B,).

    This is the paper's Fig-4 metric ("normalized importance of the top 20%
    features") — position-agnostic.
    """
    top = jax.lax.top_k(imp, k)[0]
    return jnp.sum(top, axis=-1)


def achieved_skewness(imp, k):
    """Normalised importance mass of the *first* k channels, (B,).

    Position-aware: this is what the trained extractor must deliver at
    runtime, where the XAI tool is unavailable and the split is by position.
    """
    return jnp.sum(imp[:, :k], axis=-1)


def disorder_rate(imp, k):
    """Fraction of samples where some channel >= k outranks a channel < k."""
    viol = jnp.max(imp[:, k:], axis=-1) > jnp.min(imp[:, :k], axis=-1)
    return jnp.mean(viol.astype(jnp.float32))
