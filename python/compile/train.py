"""Offline joint training of AgileNN (paper §3-§5) and all baseline schemes.

This is where the paper's thesis lives: everything expensive — XAI
attribution, skewness manipulation, channel pre-selection, the mapping layer —
happens here, at build time, so the exported artifacts need zero of it at
serving time.

Pipeline (train_agilenn):
  1. pre-train extractor + reference head end-to-end (gives XAI a
     well-trained network to attribute against, §2.2);
  2. Algorithm 1 — pick the k channels where top-k-important features most
     often land, over the training set (§5);
  3. re-initialise the 1x1 mapping layer as the permutation that moves the
     selected channels to the front (§5, Fig 12);
  4. joint training of extractor + mapping + Local NN + Remote NN + alpha
     with L = lam*L_pred + (1-lam)*(L_skew + L_dis) (§4.2), IG/GS importance
     from the frozen reference (§3.1), quantisation noise on the transmitted
     features;
  5. fold the mapping layer into the extractor (exact; DESIGN.md §4) and
     measure accuracies.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import data, losses, models, quantize, xai

Params = Any


@dataclasses.dataclass
class AgileConfig:
    dataset: str = "cifar100s"
    k: int = 5                 # top-k channels retained locally (20% of C=24)
    rho: float = 0.8           # skewness requirement
    lam: float = losses.DEFAULT_LAMBDA
    T: float = losses.DEFAULT_T
    xai_tool: str = "ig"       # "ig" | "gs"
    ig_steps: int = xai.IG_STEPS
    pre_steps: int = 350       # reference pre-training steps
    joint_steps: int = 700
    batch_size: int = 128
    lr: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 5e-4  # paper §7
    quant_noise_bits: int = 4   # train-time robustness to runtime quantization
    ordering_loss: str = "disorder"  # "disorder" | "descending" (Fig 9)
    preselect: bool = True      # Algorithm 1 on/off (Fig 11)
    preselect_samples: int = 2048  # training samples scanned by Algorithm 1
    seed: int = 0


# ---------------------------------------------------------------------------
# minimal SGD + momentum + weight decay over pytrees
# ---------------------------------------------------------------------------


def sgd_init(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd_step(params, grads, vel, *, lr, momentum, weight_decay):
    new_v = jax.tree_util.tree_map(
        lambda p, g, v: momentum * v + g + weight_decay * p, params, grads, vel
    )
    new_p = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, new_v)
    return new_p, new_v


def cosine_lr(base, step, total, *, warmup_frac=0.1):
    """Cosine schedule with linear warmup. The warmup matters: the deeper
    inverted-residual baselines (DeepCOD decoder, edge-only) die into a
    saturated-ReLU6 region if hit with the full LR + momentum at step 0."""
    warmup = max(1, int(total * warmup_frac))
    scale = min(1.0, (step + 1) / warmup)
    return base * scale * 0.5 * (1.0 + np.cos(np.pi * step / max(total, 1)))


# ---------------------------------------------------------------------------
# phase 1: reference pre-training (extractor + reference head)
# ---------------------------------------------------------------------------


def train_reference(cfg: AgileConfig, x_train, y_train):
    spec = data.SPECS[cfg.dataset]
    key = jax.random.PRNGKey(cfg.seed)
    ke, kr = jax.random.split(key)
    ext = models.init_extractor(ke)
    ref = models.init_reference(kr, models.FEATURE_CHANNELS, spec.num_classes)
    params = {"ext": ext, "ref": ref}
    vel = sgd_init(params)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            feats = models.extractor_apply(p["ext"], xb)
            logits = models.reference_apply(p["ref"], feats)
            return losses.cross_entropy(logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, vel = sgd_step(
            params, grads, vel, lr=lr, momentum=cfg.momentum, weight_decay=cfg.weight_decay
        )
        return params, vel, loss

    it = data.batches(x_train, y_train, cfg.batch_size, seed=cfg.seed + 1, epochs=10_000)
    hist = []
    for i in range(cfg.pre_steps):
        xb, yb = next(it)
        params, vel, loss = step(params, vel, jnp.asarray(xb), jnp.asarray(yb),
                                 cosine_lr(cfg.lr, i, cfg.pre_steps))
        hist.append(float(loss))
    return params["ext"], params["ref"], hist


# ---------------------------------------------------------------------------
# phase 2: Algorithm 1 — channel pre-selection
# ---------------------------------------------------------------------------


def select_channels(cfg: AgileConfig, ext, ref, x_train, y_train, *, max_samples=None):
    """Likelihood p_c that channel c hosts a top-k-important feature (Alg. 1)."""
    if max_samples is None:
        max_samples = cfg.preselect_samples
    imp_fn = xai.importance_fn(cfg.xai_tool)

    @jax.jit
    def batch_importance(xb, yb):
        feats = models.extractor_apply(ext, xb)
        return imp_fn(ref, feats, yb)

    n = min(max_samples, len(x_train))
    c = models.FEATURE_CHANNELS
    p = np.zeros(c, dtype=np.float64)
    bs = 256
    for i in range(0, n, bs):
        xb = jnp.asarray(x_train[i : i + bs])
        yb = jnp.asarray(y_train[i : i + bs])
        imp = np.asarray(batch_importance(xb, yb))  # (b, C)
        topk = np.argpartition(-imp, cfg.k - 1, axis=1)[:, : cfg.k]
        for row in topk:
            p[row] += 1.0 / n
    ranking = np.argsort(-p)
    return ranking[: cfg.k].tolist(), p.tolist()


def permutation_mapping(selected: list[int], c: int) -> dict:
    """1x1 mapping initialised as the permutation moving `selected` first."""
    order = list(selected) + [j for j in range(c) if j not in selected]
    m = np.zeros((c, c), dtype=np.float32)
    for dst, src in enumerate(order):
        m[src, dst] = 1.0
    return {"m": jnp.asarray(m)}


# ---------------------------------------------------------------------------
# phase 3: joint training
# ---------------------------------------------------------------------------


def _quant_noise(key, feats, bits):
    """Uniform noise matching a `bits`-wide quantizer's step size — makes the
    remote NN robust to the runtime codebook quantization (straight-through
    analogue of [4]'s soft-to-hard VQ)."""
    if bits <= 0:
        return feats
    # features are post-ReLU; dynamic range estimated per batch
    step = (jnp.max(feats) - jnp.min(feats)) / (2.0**bits)
    return feats + jax.random.uniform(key, feats.shape, minval=-step / 2, maxval=step / 2)


@dataclasses.dataclass
class TrainResult:
    ext: Params          # extractor with mapping folded in (deploy form)
    local: Params
    remote: Params
    ref: Params
    alpha: float
    w_alpha: float
    selected_channels: list[int]
    channel_likelihood: list[float]
    history: dict[str, list[float]]
    cfg: AgileConfig


def make_joint_step(cfg: AgileConfig, ref, num_classes: int) -> Callable:
    imp_fn = xai.importance_fn(cfg.xai_tool)

    @jax.jit
    def step(params, vel, xb, yb, key, lr):
        def loss_fn(p):
            feats = models.extractor_apply(p["ext"], xb, mapping=p["map"])
            # reference-correctness mask (§3.1): only trust XAI where the
            # frozen reference classifies correctly.
            ref_logits = models.reference_apply(ref, jax.lax.stop_gradient(feats))
            mask = (jnp.argmax(ref_logits, axis=-1) == yb).astype(jnp.float32)
            mask = jax.lax.stop_gradient(mask)
            imp = imp_fn(ref, feats, yb)

            local_logits = models.local_apply(p["local"], feats[..., : cfg.k])
            remote_in = _quant_noise(key, feats[..., cfg.k :], cfg.quant_noise_bits)
            remote_logits = models.remote_apply(p["remote"], remote_in)
            alpha = losses.alpha_of(p["w_alpha"], T=cfg.T)
            logits = losses.combine_predictions(local_logits, remote_logits, alpha)

            l_pred = losses.cross_entropy(logits, yb)
            l_skew = losses.skewness_loss(imp, cfg.k, cfg.rho, sample_mask=mask)
            if cfg.ordering_loss == "descending":
                l_dis = losses.descending_sort_loss(imp, sample_mask=mask)
            else:
                l_dis = losses.disorder_loss(imp, cfg.k, sample_mask=mask)
            total = losses.combined_loss(l_pred, l_skew, l_dis, lam=cfg.lam)
            acc = jnp.mean((jnp.argmax(logits, -1) == yb).astype(jnp.float32))
            skew = jnp.mean(xai.achieved_skewness(imp, cfg.k))
            return total, (l_pred, l_skew, l_dis, acc, skew)

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, vel = sgd_step(
            params, grads, vel, lr=lr, momentum=cfg.momentum, weight_decay=cfg.weight_decay
        )
        return params, vel, loss, aux

    return step


def train_agilenn(cfg: AgileConfig, *, log_every: int = 0) -> TrainResult:
    spec = data.SPECS[cfg.dataset]
    x_train, y_train = data.load(cfg.dataset, "train")

    # phase 1: reference pre-training
    ext, ref, pre_hist = train_reference(cfg, x_train, y_train)

    # phase 2+3: channel pre-selection -> permutation mapping init
    c = models.FEATURE_CHANNELS
    if cfg.preselect:
        selected, likelihood = select_channels(cfg, ext, ref, x_train, y_train)
        mapping = permutation_mapping(selected, c)
    else:  # Fig 11 ablation: random channels, identity-ish mapping
        rng = np.random.default_rng(cfg.seed)
        selected = rng.choice(c, cfg.k, replace=False).tolist()
        likelihood = [1.0 / c] * c
        mapping = permutation_mapping(selected, c)

    # phase 4: joint training
    key = jax.random.PRNGKey(cfg.seed + 17)
    kl, kr, kq = jax.random.split(key, 3)
    params = {
        "ext": ext,
        "map": mapping,
        "local": models.init_local(kl, cfg.k, spec.num_classes),
        "remote": models.init_remote(kr, c - cfg.k, spec.num_classes),
        "w_alpha": jnp.asarray(0.0, jnp.float32),  # alpha starts at 0.5
    }
    vel = sgd_init(params)
    step = make_joint_step(cfg, ref, spec.num_classes)

    it = data.batches(x_train, y_train, cfg.batch_size, seed=cfg.seed + 2, epochs=10_000)
    hist = {"loss": [], "pred": [], "skew_loss": [], "dis_loss": [], "acc": [],
            "skew": [], "pre": pre_hist}
    joint_lr = cfg.lr * 0.4  # extractor is warm; lower lr stabilises the joint phase
    for i in range(cfg.joint_steps):
        xb, yb = next(it)
        kq, ks = jax.random.split(kq)
        params, vel, loss, (lp, lsk, ldis, acc, skew) = step(
            params, vel, jnp.asarray(xb), jnp.asarray(yb), ks,
            # no warmup here: the extractor is already pre-trained (that is
            # the point of pre-processing), and a warmed-up prediction loss
            # lets the easier skewness losses run away early (observed:
            # skew overshooting to ~0.97 with accuracy collapse)
            cosine_lr(joint_lr, i, cfg.joint_steps, warmup_frac=0.0),
        )
        hist["loss"].append(float(loss))
        hist["pred"].append(float(lp))
        hist["skew_loss"].append(float(lsk))
        hist["dis_loss"].append(float(ldis))
        hist["acc"].append(float(acc))
        hist["skew"].append(float(skew))
        if log_every and i % log_every == 0:
            print(
                f"[{cfg.dataset}] step {i:4d} loss={float(loss):.4f} "
                f"pred={float(lp):.4f} skew={float(skew):.3f} acc={float(acc):.3f}"
            )

    # phase 5: fold the mapping layer away (deploy form)
    ext_deploy = models.fold_mapping(params["ext"], params["map"])
    alpha = float(losses.alpha_of(params["w_alpha"], T=cfg.T))
    return TrainResult(
        ext=ext_deploy,
        local=params["local"],
        remote=params["remote"],
        ref=ref,
        alpha=alpha,
        w_alpha=float(params["w_alpha"]),
        selected_channels=selected,
        channel_likelihood=likelihood,
        history=hist,
        cfg=cfg,
    )


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------


def agile_forward(res: TrainResult, xb, *, alpha=None):
    feats = models.extractor_apply(res.ext, xb)
    local_logits = models.local_apply(res.local, feats[..., : res.cfg.k])
    remote_logits = models.remote_apply(res.remote, feats[..., res.cfg.k :])
    a = res.alpha if alpha is None else alpha
    return losses.combine_predictions(local_logits, remote_logits, a), feats


def eval_agilenn(res: TrainResult, x_test, y_test, *, alpha=None, quant_codebook=None,
                 batch=256) -> float:
    """Test accuracy; optionally quantize the transmitted features through a
    codebook (the runtime path) before the remote NN."""
    correct = 0
    fwd_local = jax.jit(lambda p, x: models.local_apply(p["local"],
                        models.extractor_apply(p["ext"], x)[..., : res.cfg.k]))
    a = res.alpha if alpha is None else alpha
    pe = {"ext": res.ext, "local": res.local}

    @jax.jit
    def feats_of(x):
        return models.extractor_apply(res.ext, x)

    @jax.jit
    def remote_of(f):
        return models.remote_apply(res.remote, f)

    for i in range(0, len(x_test), batch):
        xb = jnp.asarray(x_test[i : i + batch])
        yb = y_test[i : i + batch]
        feats = feats_of(xb)
        local_logits = np.asarray(fwd_local(pe, xb))
        remote_feats = np.asarray(feats[..., res.cfg.k :])
        if quant_codebook is not None:
            remote_feats = quantize.roundtrip(remote_feats, quant_codebook)
        remote_logits = np.asarray(remote_of(jnp.asarray(remote_feats)))
        logits = a * local_logits + (1 - a) * remote_logits
        correct += int((logits.argmax(-1) == yb).sum())
    return correct / len(x_test)


def eval_simple(apply_fn, params, x_test, y_test, *, batch=256, use_jit=True) -> float:
    # use_jit=False: eager evaluation. Inside the long-lived AOT build
    # process, jit re-tracing after dozens of prior compilations was observed
    # to return stale/incorrect programs for some baselines (deepcod/edge) —
    # the exported HLO was verified correct via the Rust PJRT path, so the
    # cross-check path avoids jit entirely.
    fwd = jax.jit(lambda x: apply_fn(params, x)) if use_jit else (lambda x: apply_fn(params, x))
    correct = 0
    for i in range(0, len(x_test), batch):
        logits = np.asarray(fwd(jnp.asarray(x_test[i : i + batch])))
        correct += int((logits.argmax(-1) == y_test[i : i + batch]).sum())
    return correct / len(x_test)


def collect_importances(res: TrainResult, x, y, *, max_samples=1024, batch=256) -> np.ndarray:
    """Per-sample channel importances of the deployed extractor, (N, C)."""
    imp_fn = xai.importance_fn(res.cfg.xai_tool)

    @jax.jit
    def batch_imp(xb, yb):
        feats = models.extractor_apply(res.ext, xb)
        return imp_fn(res.ref, feats, yb)

    out = []
    n = min(max_samples, len(x))
    for i in range(0, n, batch):
        j = min(i + batch, n)
        out.append(np.asarray(batch_imp(jnp.asarray(x[i:j]), jnp.asarray(y[i:j]))))
    return np.concatenate(out, axis=0)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


def _train_simple(init_fn, apply_fn, cfg: AgileConfig, x_train, y_train, *, steps,
                  seed_offset=0):
    spec = data.SPECS[cfg.dataset]
    params = init_fn(jax.random.PRNGKey(cfg.seed + 100 + seed_offset), spec.num_classes)
    vel = sgd_init(params)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            return losses.cross_entropy(apply_fn(p, xb), yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, vel = sgd_step(params, grads, vel, lr=lr, momentum=cfg.momentum,
                               weight_decay=cfg.weight_decay)
        return params, vel, loss

    it = data.batches(x_train, y_train, cfg.batch_size, seed=cfg.seed + 3, epochs=10_000)
    hist = []
    for i in range(steps):
        xb, yb = next(it)
        params, vel, loss = step(params, vel, jnp.asarray(xb), jnp.asarray(yb),
                                 cosine_lr(cfg.lr, i, steps))
        hist.append(float(loss))
    return params, hist


def train_deepcod(cfg: AgileConfig, x_train, y_train, *, steps=600, sparsity=1e-4):
    """DeepCOD [65]: device encoder + remote decoder/classifier, end-to-end
    with an L1 sparsity regulariser on the transmitted code."""
    spec = data.SPECS[cfg.dataset]
    params = models.init_deepcod(jax.random.PRNGKey(cfg.seed + 200), spec.num_classes)
    vel = sgd_init(params)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            code = models.deepcod_encode(p, xb)
            logits = models.deepcod_decode(p, code)
            return losses.cross_entropy(logits, yb) + sparsity * jnp.mean(jnp.abs(code))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, vel = sgd_step(params, grads, vel, lr=lr, momentum=cfg.momentum,
                               weight_decay=cfg.weight_decay)
        return params, vel, loss

    it = data.batches(x_train, y_train, cfg.batch_size, seed=cfg.seed + 4, epochs=10_000)
    hist = []
    for i in range(steps):
        xb, yb = next(it)
        params, vel, loss = step(params, vel, jnp.asarray(xb), jnp.asarray(yb),
                                 cosine_lr(cfg.lr, i, steps))
        hist.append(float(loss))
    return params, hist


def train_spinn(cfg: AgileConfig, x_train, y_train, *, steps=600, exit_weight=0.3):
    """SPINN [39]: partitioned net trained with joint early-exit + final loss."""
    spec = data.SPECS[cfg.dataset]
    params = models.init_spinn(jax.random.PRNGKey(cfg.seed + 300), spec.num_classes)
    vel = sgd_init(params)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            feats, exit_logits = models.spinn_device(p, xb)
            final_logits = models.spinn_remote(p, feats)
            return (1 - exit_weight) * losses.cross_entropy(final_logits, yb) + \
                exit_weight * losses.cross_entropy(exit_logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, vel = sgd_step(params, grads, vel, lr=lr, momentum=cfg.momentum,
                               weight_decay=cfg.weight_decay)
        return params, vel, loss

    it = data.batches(x_train, y_train, cfg.batch_size, seed=cfg.seed + 5, epochs=10_000)
    hist = []
    for i in range(steps):
        xb, yb = next(it)
        params, vel, loss = step(params, vel, jnp.asarray(xb), jnp.asarray(yb),
                                 cosine_lr(cfg.lr, i, steps))
        hist.append(float(loss))
    return params, hist


def train_mcunet(cfg: AgileConfig, x_train, y_train, *, steps=600):
    return _train_simple(models.init_mcunet, models.mcunet_apply, cfg, x_train, y_train,
                         steps=steps, seed_offset=1)


def train_edgeonly(cfg: AgileConfig, x_train, y_train, *, steps=600):
    return _train_simple(models.init_edgeonly, models.edgeonly_apply, cfg, x_train, y_train,
                         steps=steps, seed_offset=2)
