"""Fig 6: joint-training stability vs feature-extractor depth, from scratch.

The paper shows that training AgileNN from scratch (no reference
pre-training, no Algorithm-1 pre-processing) is unstable unless the
extractor has >= 6 conv layers. We reproduce the instability signal as the
variance/level of the training loss without pre-processing vs with it.
"""

from __future__ import annotations

import sys

import numpy as np

from .. import train
from .common import emit, out_dir, quick_flag


def run(out, *, quick=False):
    steps = 40 if quick else 150
    rows = []
    for preselect, pre_steps, label in [
        (False, 1, "scratch (no pre-processing)"),
        (True, 40 if quick else 200, "pre-processed (AgileNN)"),
    ]:
        cfg = train.AgileConfig(
            dataset="cifar100s",
            pre_steps=pre_steps,
            joint_steps=steps,
            ig_steps=2,
            preselect=preselect,
            preselect_samples=256,
        )
        res = train.train_agilenn(cfg)
        losses = np.asarray(res.history["pred"])
        accs = np.asarray(res.history["acc"])
        rows.append([
            label,
            float(losses[: steps // 4].mean()),
            float(losses[-steps // 4 :].mean()),
            float(np.std(np.diff(losses))),  # step-to-step oscillation
            float(accs[-steps // 4 :].mean()),
        ])
    emit(out, "fig06",
         "Fig 6: training stability, scratch vs pre-processed feature extractor",
         ["setup", "early_loss", "late_loss", "loss_oscillation", "late_acc"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
