"""Fig 11: effectiveness of pre-processing (Algorithm 1 channel selection)
vs random channel selection — random init brings learning difficulty from
the first epochs and worse convergence.
"""

from __future__ import annotations

import sys

import numpy as np

from .. import data, train
from .common import emit, out_dir, quick_flag


def run(out, *, quick=False):
    x_test, y_test = data.load("cifar100s", "test")
    steps = 60 if quick else 250
    rows = []
    for preselect, label in [(True, "Algorithm 1"), (False, "random channels")]:
        cfg = train.AgileConfig(
            dataset="cifar100s",
            preselect=preselect,
            pre_steps=60 if quick else 250,
            joint_steps=steps,
            ig_steps=2,
            preselect_samples=256,
        )
        res = train.train_agilenn(cfg)
        acc = train.eval_agilenn(res, x_test[:256], y_test[:256])
        losses = np.asarray(res.history["pred"])
        rows.append([
            label,
            float(losses[: steps // 4].mean()),
            float(losses[-steps // 4 :].mean()),
            acc,
        ])
    emit(out, "fig11", "Fig 11: Algorithm-1 pre-processing vs random channel init",
         ["channel_init", "early_pred_loss", "late_pred_loss", "accuracy"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
