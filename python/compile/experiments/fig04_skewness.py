"""Fig 4: natural skewness of feature importance, before any manipulation.

Trains the reference (no skewness losses), evaluates IG importance of the
raw extractor features, and reports the distribution of the top-20% mass —
reproducing the paper's observation that >40% of CIFAR-10/100 samples have
skewness < 50%... i.e. that skewness must be *manufactured*.
"""

from __future__ import annotations

import sys

import jax.numpy as jnp
import numpy as np

from .. import data, models, train, xai
from .common import emit, out_dir, quick_flag


def run(out, *, quick=False):
    rows = []
    for ds in ["cifar10s", "cifar100s"]:
        cfg = train.AgileConfig(dataset=ds, pre_steps=60 if quick else 300)
        x, y = data.load(ds, "train")
        ext, ref, _ = train.train_reference(cfg, x, y)
        feats = models.extractor_apply(ext, jnp.asarray(x[:512]))
        imp = xai.ig_importance(ref, feats, jnp.asarray(y[:512]), steps=4)
        k = max(1, int(0.2 * models.FEATURE_CHANNELS))  # top 20% of features
        skew = np.asarray(xai.natural_skewness(imp, k))
        rows.append([
            ds,
            float(np.mean(skew)),
            float(np.quantile(skew, 0.1)),
            float(np.quantile(skew, 0.5)),
            float(np.quantile(skew, 0.9)),
            float((skew < 0.5).mean()),
        ])
    emit(out, "fig04", "Fig 4: natural importance skewness (top-20% mass, no manipulation)",
         ["dataset", "mean", "p10", "p50", "p90", "frac_below_50%"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
