"""Fig 8: the alpha(w;T) soft constraint — sensitivity and accuracy vs T.

(a) alpha's trajectory range for different temperatures T;
(b) final accuracy vs T: very small T lets alpha saturate toward 0/1 and
    starve one branch (the paper's bias failure mode); T in [4,8] is safe.
"""

from __future__ import annotations

import sys

import numpy as np

from .. import data, losses, train
from .common import emit, out_dir, quick_flag


def run(out, *, quick=False):
    x_test, y_test = data.load("svhns", "test")
    steps = 60 if quick else 250
    rows = []
    for t in [1.0, 2.0, 4.0, 6.0, 8.0, 16.0]:
        cfg = train.AgileConfig(
            dataset="svhns",
            T=t,
            pre_steps=60 if quick else 250,
            joint_steps=steps,
            ig_steps=2,
            preselect_samples=256,
        )
        res = train.train_agilenn(cfg)
        acc = train.eval_agilenn(res, x_test[:256], y_test[:256])
        # sensitivity: |d alpha / d w| at the trained w
        eps = 1e-3
        sens = abs(
            float(losses.alpha_of(np.float32(res.w_alpha + eps), T=t))
            - float(losses.alpha_of(np.float32(res.w_alpha - eps), T=t))
        ) / (2 * eps)
        rows.append([t, res.alpha, sens, acc])
    emit(out, "fig08", "Fig 8: alpha soft-constraint temperature T",
         ["T", "trained_alpha", "d_alpha/d_w", "accuracy"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
