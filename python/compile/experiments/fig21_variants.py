"""Fig 21 sweep: train 9 AgileNN variants over (k, rho) in
{3,5,7} x {0.7,0.8,0.9} (10/20/30% features local x skewness targets), and
write slim per-variant metas to artifacts/fig21/k{K}_rho{R}/meta.json for
`agilenn bench --figure 21`.

Slow (9 trainings) — opt-in via `make fig21-train`. --quick shrinks steps.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from .. import data, models, quantize, train, xai


def run_variant(ds: str, k: int, rho: float, out_root: pathlib.Path, *, quick: bool):
    cfg = train.AgileConfig(
        dataset=ds,
        k=k,
        rho=rho,
        pre_steps=60 if quick else 250,
        joint_steps=80 if quick else 300,
        ig_steps=2 if quick else 4,
        preselect_samples=256 if quick else 1024,
    )
    res = train.train_agilenn(cfg)
    x_test, y_test = data.load(ds, "test")
    n = 256
    acc = train.eval_agilenn(res, x_test[:n], y_test[:n])
    imps = train.collect_importances(res, x_test, y_test, max_samples=n)
    ach = float(np.asarray(xai.achieved_skewness(jnp.asarray(imps), k)).mean())

    # mean transmitted payload: 4-bit quantized entropy estimate over the
    # remote features (the Rust side recomputes exact LZW sizes for the main
    # trained point; here the entropy bound keeps the sweep fast)
    feats_fn = jax.jit(lambda xb: models.extractor_apply(res.ext, xb))
    feats = np.asarray(feats_fn(jnp.asarray(x_test[:n])))[..., k:]
    cb = quantize.fit_codebook(feats, 4)
    ent = quantize.code_entropy_bits(quantize.quantize(feats, cb))
    elems = feats.shape[1] * feats.shape[2] * feats.shape[3]
    payload_bytes = elems * ent / 8.0 + 4

    vdir = out_root / f"k{k}_rho{int(rho * 100)}"
    vdir.mkdir(parents=True, exist_ok=True)
    meta = {
        "k": k,
        "rho": rho,
        "accuracy": acc,
        "achieved_skewness": ach,
        "mean_tx_payload_bytes": payload_bytes,
        "alpha": res.alpha,
        "dataset": ds,
    }
    (vdir / "meta.json").write_text(json.dumps(meta, indent=1))
    print(f"k={k} rho={rho}: acc={acc:.3f} skew={ach:.3f} payload~{payload_bytes:.0f}B")
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/fig21")
    ap.add_argument("--dataset", default="cifar10s")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    out_root = pathlib.Path(args.out)
    # paper §7.4: retain 10/20/30% of features with rho 0.7/0.8/0.9
    for k in (3, 5, 7):
        for rho in (0.7, 0.8, 0.9):
            run_variant(args.dataset, k, rho, out_root, quick=args.quick)


if __name__ == "__main__":
    main()
