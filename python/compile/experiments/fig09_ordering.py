"""Fig 9: feature ordering — strict descending-sort loss vs the relaxed
disorder loss (Eq. 1). The strict loss costs accuracy; the disorder loss
achieves <2% disorder cases without hurting accuracy.
"""

from __future__ import annotations

import sys

import numpy as np

from .. import data, train, xai
from .common import emit, out_dir, quick_flag


def run(out, *, quick=False):
    x_test, y_test = data.load("cifar10s", "test")
    steps = 60 if quick else 300
    rows = []
    for ordering in ["descending", "disorder"]:
        cfg = train.AgileConfig(
            dataset="cifar10s",
            ordering_loss=ordering,
            pre_steps=60 if quick else 250,
            joint_steps=steps,
            ig_steps=2,
            preselect_samples=256,
        )
        res = train.train_agilenn(cfg)
        acc = train.eval_agilenn(res, x_test[:256], y_test[:256])
        imps = train.collect_importances(res, x_test, y_test, max_samples=256)
        import jax.numpy as jnp

        dis = float(np.asarray(xai.disorder_rate(jnp.asarray(imps), cfg.k)))
        rows.append([ordering, acc, dis])
    emit(out, "fig09", "Fig 9: descending-sort loss vs relaxed disorder loss",
         ["ordering_loss", "accuracy", "disorder_rate"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
