"""Run every training-side figure experiment (Figs 4, 6, 8, 9, 10, 11, 15,
24). `--quick` shrinks all trainings for smoke runs.

    cd python && python -m compile.experiments.run_all --out ../artifacts/figures
"""

from __future__ import annotations

import argparse
import time

from . import (
    fig04_skewness,
    fig06_stability,
    fig08_alpha_T,
    fig09_ordering,
    fig10_lambda,
    fig11_preproc,
    fig15_convergence,
    fig24_xai,
)
from .common import out_dir

MODULES = [
    ("fig04", fig04_skewness),
    ("fig06", fig06_stability),
    ("fig08", fig08_alpha_T),
    ("fig09", fig09_ordering),
    ("fig10", fig10_lambda),
    ("fig11", fig11_preproc),
    ("fig15", fig15_convergence),
    ("fig24", fig24_xai),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/figures")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="", help="comma-separated subset, e.g. fig04,fig10")
    args = ap.parse_args()
    out = out_dir(args.out)
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    for name, mod in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"--- {name} ---")
        mod.run(out, quick=args.quick)
        print(f"[{name} done in {time.time() - t0:.0f}s]\n")


if __name__ == "__main__":
    main()
