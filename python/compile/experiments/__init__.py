# Training-side figure regeneration (paper Figs 4, 6, 8, 9, 10, 11, 15, 21, 24).
# Each module exposes run(out_dir) and is runnable as `python -m
# compile.experiments.<name>`; `run_all` drives the whole set.
