"""Fig 24: choice of XAI technique — Integrated Gradients vs Gradient
Saliency. Trains one AgileNN variant per tool and writes fig24.json, which
`agilenn bench --figure 24` renders alongside the serving-side numbers.
"""

from __future__ import annotations

import json
import sys

import numpy as np

from .. import data, train
from .common import emit, out_dir, quick_flag


def run(out, *, quick=False):
    x_test, y_test = data.load("svhns", "test")
    steps = 60 if quick else 300
    points = []
    rows = []
    for tool, grads_per_eval in [("ig", 4), ("gs", 1)]:
        cfg = train.AgileConfig(
            dataset="svhns",
            xai_tool=tool,
            pre_steps=60 if quick else 250,
            joint_steps=steps,
            ig_steps=4,
            preselect_samples=256,
        )
        res = train.train_agilenn(cfg)
        acc = train.eval_agilenn(res, x_test[:256], y_test[:256])
        skew = float(np.mean(res.history["skew"][-25:]))
        points.append({
            "dataset": "svhns",
            "tool": tool,
            "accuracy": acc,
            "achieved_skewness": skew,
            "grad_computations_per_eval": grads_per_eval,
        })
        rows.append([tool.upper(), acc, skew, grads_per_eval])
    (out / "fig24.json").write_text(json.dumps(points, indent=1))
    emit(out, "fig24_table", "Fig 24: IG vs Gradient Saliency",
         ["tool", "accuracy", "achieved_skewness", "grads/eval"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
