"""Fig 10: the lambda sweep — prediction-vs-skewness weighting (§4.2).

Small lambda => skewness dominates, accuracy suffers; large lambda =>
skewness target missed. The paper lands on lambda in [0.2, 0.4].
"""

from __future__ import annotations

import sys

import numpy as np

from .. import data, train
from .common import emit, out_dir, quick_flag


def run(out, *, quick=False):
    x_test, y_test = data.load("cifar100s", "test")
    steps = 60 if quick else 250
    rows = []
    for lam in [0.1, 0.2, 0.3, 0.4, 0.6, 0.9]:
        cfg = train.AgileConfig(
            dataset="cifar100s",
            lam=lam,
            pre_steps=60 if quick else 250,
            joint_steps=steps,
            ig_steps=2,
            preselect_samples=256,
        )
        res = train.train_agilenn(cfg)
        acc = train.eval_agilenn(res, x_test[:256], y_test[:256])
        skew = float(np.mean(res.history["skew"][-25:]))
        rows.append([lam, skew, acc])
    emit(out, "fig10", "Fig 10: lambda (prediction vs skewness weighting)",
         ["lambda", "achieved_skewness", "accuracy"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
