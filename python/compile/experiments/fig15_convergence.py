"""Fig 15: training convergence — AgileNN's joint training (with XAI losses)
vs regular training of the same capacity, on CIFAR-100-s and SVHN-s.

The paper's point: skewness manipulation does not slow convergence.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from .. import data, losses as L, models, train
from .common import emit, out_dir, quick_flag


def _train_regular(cfg, x_train, y_train, steps):
    """Same extractor + remote-NN capacity, plain cross-entropy."""
    spec = data.SPECS[cfg.dataset]
    key = jax.random.PRNGKey(cfg.seed + 999)
    ke, kr = jax.random.split(key)
    params = {
        "ext": models.init_extractor(ke),
        "net": models.init_remote(kr, models.FEATURE_CHANNELS, spec.num_classes),
    }
    vel = train.sgd_init(params)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            feats = models.extractor_apply(p["ext"], xb)
            logits = models.remote_apply(p["net"], feats)
            return L.cross_entropy(logits, yb), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, vel = train.sgd_step(params, grads, vel, lr=lr, momentum=cfg.momentum,
                                     weight_decay=cfg.weight_decay)
        acc = jnp.mean((jnp.argmax(logits, -1) == yb).astype(jnp.float32))
        return params, vel, loss, acc

    it = data.batches(x_train, y_train, cfg.batch_size, seed=cfg.seed + 7, epochs=10_000)
    hist = {"loss": [], "acc": []}
    for i in range(steps):
        xb, yb = next(it)
        params, vel, loss, acc = step(params, vel, jnp.asarray(xb), jnp.asarray(yb),
                                      train.cosine_lr(cfg.lr, i, steps))
        hist["loss"].append(float(loss))
        hist["acc"].append(float(acc))
    return hist


def run(out, *, quick=False):
    steps = 60 if quick else 300
    rows = []
    for ds in ["cifar100s", "svhns"]:
        cfg = train.AgileConfig(dataset=ds, pre_steps=60 if quick else 250,
                                joint_steps=steps, ig_steps=2, preselect_samples=256)
        x_train, y_train = data.load(ds, "train")
        res = train.train_agilenn(cfg)
        reg = _train_regular(cfg, x_train, y_train, steps)
        for quarter in range(4):
            lo, hi = quarter * steps // 4, (quarter + 1) * steps // 4
            rows.append([
                ds,
                f"q{quarter + 1}",
                float(np.mean(res.history["pred"][lo:hi])),
                float(np.mean(res.history["acc"][lo:hi])),
                float(np.mean(reg["loss"][lo:hi])),
                float(np.mean(reg["acc"][lo:hi])),
            ])
    emit(out, "fig15", "Fig 15: convergence — AgileNN joint training vs regular training",
         ["dataset", "phase", "agile_loss", "agile_acc", "regular_loss", "regular_acc"], rows)


if __name__ == "__main__":
    run(out_dir(), quick=quick_flag(sys.argv))
