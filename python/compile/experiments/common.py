"""Shared helpers for the training-side figure experiments."""

from __future__ import annotations

import json
import pathlib

DEFAULT_OUT = pathlib.Path("../artifacts/figures")


def out_dir(arg: str | None = None) -> pathlib.Path:
    d = pathlib.Path(arg) if arg else DEFAULT_OUT
    d.mkdir(parents=True, exist_ok=True)
    return d


def emit(out: pathlib.Path, name: str, title: str, headers: list[str], rows: list[list]) -> None:
    """Print an aligned table and persist it as JSON for the Rust side."""
    widths = [len(h) for h in headers]
    srows = [[f"{c:.4f}" if isinstance(c, float) else str(c) for c in r] for r in rows]
    for r in srows:
        widths = [max(w, len(c)) for w, c in zip(widths, r)]
    print(f"== {title} ==")
    print("  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    print("-" * (sum(widths) + 2 * (len(widths) - 1)))
    for r in srows:
        print("  ".join(c.ljust(w) for c, w in zip(r, widths)))
    print()
    (out / f"{name}.json").write_text(
        json.dumps({"title": title, "headers": headers, "rows": rows}, indent=1)
    )


def quick_flag(argv: list[str]) -> bool:
    return "--quick" in argv
