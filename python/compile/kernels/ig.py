"""Pallas kernel for Integrated-Gradients accumulation (L1, offline hot spot).

The paper reports a 3-4x wall-clock increase per training epoch from XAI
evaluation (§7.1); the dominant cost after the S reference-NN backward passes
is the attribution reduction over the (S, B, H, W, C) gradient tensor.  This
kernel fuses the path-integral mean over S, the (x - x0) * avg_grad product,
the spatial |.| reduction, and the per-sample L1 normalisation into a single
VMEM-resident pass per sample:

  grid = (B,)                 one program per sample
  grads block : (S, H, W, C)  all interpolation-point gradients -> VMEM
  feats block : (H, W, C)                                       -> VMEM
  out   block : (C,)          normalised channel importance

VMEM per program at training shapes (S=8, H=W=8, C=24, f32): 8*8*8*24*4 =
48 KiB grads + 6 KiB feats — one HBM read per element, zero intermediate
round-trips (the naive jnp version materialises the (S,B,H,W,C) product and
the (B,H,W,C) IG map in HBM).

interpret=True for the same reason as extractor_conv (CPU PJRT target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ig_kernel(feats_ref, grads_ref, o_ref):
    feats = feats_ref[0]  # (H, W, C) — unit batch dim in the block
    grads = grads_ref[:, 0]  # (S, H, W, C)
    avg_grad = jnp.mean(grads, axis=0)
    ig = feats * avg_grad  # zero baseline: (x - 0) * avg_grad
    imp = jnp.sum(jnp.abs(ig), axis=(0, 1))  # (C,)
    o_ref[0] = imp / (jnp.sum(imp) + 1e-9)


def ig_channel_importance(feats, grads):
    """feats: (B,H,W,C); grads: (S,B,H,W,C) -> (B,C) normalised importance."""
    s, b, h, w, c = grads.shape
    if feats.shape != (b, h, w, c):
        raise ValueError(f"feats {feats.shape} mismatches grads {grads.shape}")
    return pl.pallas_call(
        _ig_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, c), lambda n: (n, 0, 0, 0)),
            pl.BlockSpec((s, 1, h, w, c), lambda n: (0, n, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(feats, grads)
