"""Pallas kernel for the on-device feature extractor's convolutions (L1).

This is the paper's *online* hot spot: the only NN compute that runs on the
embedded device is the 2-conv feature extractor, so its conv is the kernel we
hand-schedule.  The conv is expressed the TPU-native way — as a sum of nine
shifted `(Ho*Wo, Cin) x (Cin, Cout)` matmuls (one per 3x3 tap), which on real
hardware map straight onto the MXU systolic array, with the whole per-image
activation block resident in VMEM:

  grid = (B,)                      one program per image
  x block   : (H+2, W+2, Cin)      padded activations  -> VMEM
  w block   : (3, 3, Cin, Cout)    weights (replicated) -> VMEM
  out block : (Ho, Wo, Cout)                            -> VMEM

VMEM footprint per program (f32, extractor conv2: H=16, Cin=16, Cout=24):
  x 18*18*16*4 = 20.7 KiB, w 3*3*16*24*4 = 13.8 KiB, out 8*8*24*4 = 6 KiB
  -> ~41 KiB, far under the ~16 MiB VMEM budget; the grid could be widened to
  batch tiles of 64+ images per program on a real TPU (see EXPERIMENTS.md
  §Perf for the block-shape sweep).

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the same dataflow to plain HLO so the
exported artifact runs on the Rust side unchanged.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KH = KW = 3  # the extractor uses 3x3 convs only


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, relu: bool):
    """One image: 'SAME' 3x3 conv as 9 tap-matmuls accumulated in f32."""
    x = x_ref[0]  # (H+2, W+2, Cin) — already padded; block carries a unit batch dim
    w = w_ref[...]  # (3, 3, Cin, Cout)
    b = b_ref[...]
    _, ho, wo, cout = o_ref.shape
    cin = x.shape[-1]
    acc = jnp.zeros((ho * wo, cout), jnp.float32)
    for i in range(KH):
        for j in range(KW):
            # shifted, strided activation window for tap (i, j)
            tap = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, cin),
                (stride, stride, 1),
            )  # (ho, wo, cin)
            # MXU-shaped contraction: (ho*wo, cin) @ (cin, cout)
            acc += tap.reshape(ho * wo, cin) @ w[i, j]
    out = acc.reshape(ho, wo, cout) + b
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[0] = out


def _conv2d(x, w, b, *, stride: int, relu: bool):
    if x.ndim != 4 or w.ndim != 4 or w.shape[0] != KH or w.shape[1] != KW:
        raise ValueError(f"expected NHWC x and (3,3,cin,cout) w, got {x.shape} {w.shape}")
    bsz, h, wd, cin = x.shape
    cout = w.shape[-1]
    ho, wo = -(-h // stride), -(-wd // stride)  # ceil-div, 'SAME'
    # 'SAME' padding for odd kernels: one pixel each side (stride 1) or
    # asymmetric for stride 2 on even sizes; jnp.pad once outside the kernel.
    pad_h = (ho - 1) * stride + KH - h
    pad_w = (wo - 1) * stride + KW - wd
    xp = jnp.pad(
        x,
        ((0, 0), (pad_h // 2, pad_h - pad_h // 2), (pad_w // 2, pad_w - pad_w // 2), (0, 0)),
    )
    kernel = partial(_conv_kernel, stride=stride, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, xp.shape[1], xp.shape[2], cin), lambda n: (n, 0, 0, 0)),
            pl.BlockSpec((KH, KW, cin, cout), lambda n: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, cout), lambda n: (n, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, ho, wo, cout), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, w, b)


def conv2d_relu(x, w, b, *, stride=1):
    """Fused 'SAME' 3x3 conv + bias + ReLU (NHWC)."""
    return _conv2d(x, w, b, stride=stride, relu=True)


def conv2d_linear(x, w, b, *, stride=1):
    """'SAME' 3x3 conv + bias, no activation (pre-mapping-layer conv2)."""
    return _conv2d(x, w, b, stride=stride, relu=False)
