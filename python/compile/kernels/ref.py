"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package must match its oracle to float32
tolerance; python/tests/test_kernels.py sweeps shapes/dtypes with hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, b, *, stride=1):
    """NHWC 'SAME' conv, no activation. w: (kh,kw,cin,cout)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def conv2d_relu_ref(x, w, b, *, stride=1):
    return jax.nn.relu(conv2d_ref(x, w, b, stride=stride))


def ig_channel_importance_ref(feats, grads):
    """Reference Integrated-Gradients channel importance.

    feats: (B,H,W,C) features; baseline is zero (paper §2.2).
    grads: (S,B,H,W,C) gradients of the reference NN's target logit at S
           linear interpolation points between baseline and feats.
    Returns (B,C): per-channel importance, L1-normalised per sample.
    """
    avg_grad = jnp.mean(grads, axis=0)  # path-integral approximation
    ig = feats * avg_grad  # (x - x0) * avg_grad with x0 = 0
    imp = jnp.sum(jnp.abs(ig), axis=(1, 2))  # (B,C)
    return imp / (jnp.sum(imp, axis=-1, keepdims=True) + 1e-9)
