"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

hypothesis sweeps shapes; tolerances are f32-accumulation-order level.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev-dep: skip, don't error
from hypothesis import given, settings, strategies as st

from compile.kernels import extractor_conv as ek
from compile.kernels import ig as igk
from compile.kernels import ref

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([4, 8, 16]),
    cin=st.sampled_from([1, 3, 16]),
    cout=st.sampled_from([4, 24]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 10_000),
)
def test_conv_relu_matches_ref(b, hw, cin, cout, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, hw, hw, cin)
    w = _rand(rng, 3, 3, cin, cout)
    bias = _rand(rng, cout)
    got = ek.conv2d_relu(x, w, bias, stride=stride)
    want = ref.conv2d_relu_ref(x, w, bias, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([8, 16]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 10_000),
)
def test_conv_linear_matches_ref(b, hw, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, hw, hw, 16)
    w = _rand(rng, 3, 3, 16, 24)
    bias = _rand(rng, 24)
    got = ek.conv2d_linear(x, w, bias, stride=stride)
    want = ref.conv2d_ref(x, w, bias, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_conv_relu_nonnegative():
    rng = np.random.default_rng(0)
    x, w, bias = _rand(rng, 2, 8, 8, 4), _rand(rng, 3, 3, 4, 6), _rand(rng, 6)
    out = np.asarray(ek.conv2d_relu(x, w, bias))
    assert (out >= 0).all()


def test_conv_rejects_bad_kernel_shape():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        ek.conv2d_relu(_rand(rng, 1, 8, 8, 3), _rand(rng, 5, 5, 3, 4), _rand(rng, 4))


@given(
    b=st.integers(1, 4),
    s=st.sampled_from([1, 4, 8]),
    hw=st.sampled_from([4, 8]),
    c=st.sampled_from([6, 24]),
    seed=st.integers(0, 10_000),
)
def test_ig_kernel_matches_ref(b, s, hw, c, seed):
    rng = np.random.default_rng(seed)
    feats = _rand(rng, b, hw, hw, c)
    grads = _rand(rng, s, b, hw, hw, c)
    got = igk.ig_channel_importance(feats, grads)
    want = ref.ig_channel_importance_ref(feats, grads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@given(b=st.integers(1, 3), seed=st.integers(0, 1000))
def test_ig_importance_normalised(b, seed):
    rng = np.random.default_rng(seed)
    feats = _rand(rng, b, 8, 8, 12)
    grads = _rand(rng, 4, b, 8, 8, 12)
    imp = np.asarray(igk.ig_channel_importance(feats, grads))
    assert (imp >= 0).all()
    np.testing.assert_allclose(imp.sum(axis=-1), np.ones(b), rtol=1e-4)


def test_ig_kernel_shape_mismatch_raises():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        igk.ig_channel_importance(_rand(rng, 2, 8, 8, 4), _rand(rng, 3, 2, 8, 8, 5))
