"""Make `compile` importable whether pytest runs from python/ or the repo
root (the final validation command runs `pytest python/tests/`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
