"""Make `compile` importable whether pytest runs from python/ or the repo
root (the final validation command runs `pytest python/tests/`), and skip
collecting the property-based test modules when `hypothesis` is absent —
the build environment does not always vendor it, and a missing optional
dev-dependency should skip, not error at collection."""

import importlib.util
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += [
        "test_kernels.py",
        "test_losses_xai.py",
        "test_quantize_data.py",
    ]
