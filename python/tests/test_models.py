"""L2 shape/semantic tests for the model zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models

KEY = jax.random.PRNGKey(0)
X = jnp.asarray(np.random.default_rng(1).normal(size=(2, 32, 32, 3)).astype(np.float32))


def test_extractor_shape_and_relu():
    p = models.init_extractor(KEY)
    f = models.extractor_apply(p, X)
    assert f.shape == (2, models.FEATURE_HW, models.FEATURE_HW, models.FEATURE_CHANNELS)
    assert (np.asarray(f) >= 0).all()


def test_extractor_mapping_fold_is_exact():
    p = models.init_extractor(KEY)
    m = {"m": jax.random.normal(jax.random.PRNGKey(3),
                                (models.FEATURE_CHANNELS, models.FEATURE_CHANNELS))}
    with_map = models.extractor_apply(p, X, mapping=m)
    folded = models.extractor_apply(models.fold_mapping(p, m), X)
    np.testing.assert_allclose(np.asarray(with_map), np.asarray(folded), rtol=1e-5, atol=1e-5)


def test_extractor_pallas_path_matches_jnp_path():
    p = models.init_extractor(KEY)
    a = models.extractor_apply(p, X, use_pallas=True)
    b = models.extractor_apply(p, X, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("k,nc", [(3, 10), (5, 100), (7, 200)])
def test_local_nn_shape(k, nc):
    p = models.init_local(KEY, k, nc)
    f = jnp.ones((4, 8, 8, k))
    assert models.local_apply(p, f).shape == (4, nc)


@pytest.mark.parametrize("cin,nc", [(19, 10), (21, 100)])
def test_remote_nn_shape(cin, nc):
    p = models.init_remote(KEY, cin, nc)
    f = jnp.ones((2, 8, 8, cin))
    assert models.remote_apply(p, f).shape == (2, nc)


def test_reference_nn_shape():
    p = models.init_reference(KEY, 24, 100)
    assert models.reference_apply(p, jnp.ones((2, 8, 8, 24))).shape == (2, 100)


def test_deepcod_shapes():
    p = models.init_deepcod(KEY, 10)
    code = models.deepcod_encode(p, X)
    assert code.shape == (2, 8, 8, models.DEEPCOD_CODE_CHANNELS)
    assert models.deepcod_decode(p, code).shape == (2, 10)


def test_spinn_shapes():
    p = models.init_spinn(KEY, 10)
    feats, exit_logits = models.spinn_device(p, X)
    assert feats.shape == (2, 8, 8, 32)
    assert exit_logits.shape == (2, 10)
    assert models.spinn_remote(p, feats).shape == (2, 10)


def test_mcunet_and_edgeonly_shapes():
    assert models.mcunet_apply(models.init_mcunet(KEY, 10), X).shape == (2, 10)
    assert models.edgeonly_apply(models.init_edgeonly(KEY, 10), X).shape == (2, 10)


def test_macs_ordering_matches_paper():
    """AgileNN's device compute must be far below every baseline's (Fig 16)."""
    nc = 100
    agile = models.extractor_macs() + models.local_macs(5, nc)
    assert agile < models.deepcod_encoder_macs() / 3
    assert agile < models.spinn_device_macs(nc) / 1.8
    assert agile < models.mcunet_macs(nc) / 4


def test_param_count_and_bytes():
    p = models.init_local(KEY, 5, 10)
    assert models.param_count(p) == 5 * 10 + 10
    assert models.param_bytes(p, dtype_bytes=1) == 60
