"""Training-pipeline smoke + semantics tests (tiny steps — CI-speed)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, losses, models, train, xai


@pytest.fixture(scope="module")
def tiny_result():
    cfg = train.AgileConfig(
        dataset="svhns",
        pre_steps=10,
        joint_steps=10,
        ig_steps=2,
        batch_size=32,
        preselect_samples=128,
    )
    return train.train_agilenn(cfg)


def test_train_produces_deploy_form(tiny_result):
    res = tiny_result
    # mapping layer folded away: deploy extractor has exactly two convs
    assert set(res.ext.keys()) == {"conv1", "conv2"}
    assert 0.0 < res.alpha < 1.0
    assert len(res.selected_channels) == res.cfg.k
    assert len(set(res.selected_channels)) == res.cfg.k
    assert len(res.channel_likelihood) == models.FEATURE_CHANNELS


def test_train_history_recorded(tiny_result):
    res = tiny_result
    for key in ("loss", "pred", "skew_loss", "dis_loss", "acc", "skew"):
        assert len(res.history[key]) == res.cfg.joint_steps
    assert all(np.isfinite(res.history["loss"]))


def test_skewness_moves_toward_target(tiny_result):
    # even 10 steps of skewness loss should not *decrease* skewness
    res = tiny_result
    assert res.history["skew"][-1] >= res.history["skew"][0] - 0.05


def test_eval_and_forward_shapes(tiny_result):
    res = tiny_result
    x, y = data.load("svhns", "test")
    acc = train.eval_agilenn(res, x[:64], y[:64])
    assert 0.0 <= acc <= 1.0
    logits, feats = train.agile_forward(res, jnp.asarray(x[:2]))
    assert logits.shape == (2, 10)
    assert feats.shape == (2, 8, 8, 24)


def test_collect_importances_normalised(tiny_result):
    res = tiny_result
    x, y = data.load("svhns", "test")
    imps = train.collect_importances(res, x, y, max_samples=32)
    assert imps.shape == (32, models.FEATURE_CHANNELS)
    np.testing.assert_allclose(imps.sum(axis=1), 1.0, rtol=1e-3)


def test_permutation_mapping_moves_selected_first():
    m = train.permutation_mapping([3, 1], 4)["m"]
    feats = jnp.asarray(np.arange(4, dtype=np.float32)[None, None, None, :])
    mapped = jnp.einsum("bhwc,cd->bhwd", feats, m)[0, 0, 0]
    assert mapped.tolist() == [3.0, 1.0, 0.0, 2.0]


def test_sgd_step_descends_quadratic():
    params = {"w": jnp.asarray(4.0)}
    vel = train.sgd_init(params)
    for _ in range(50):
        grads = {"w": 2.0 * params["w"]}
        params, vel = train.sgd_step(params, grads, vel, lr=0.05, momentum=0.9, weight_decay=0.0)
    assert abs(float(params["w"])) < 0.5


def test_cosine_lr_endpoints():
    # linear warmup over the first 10%: step 0 is base/warmup_steps
    assert train.cosine_lr(0.1, 0, 100) == pytest.approx(0.01, rel=1e-2)
    # warmup complete by 10%: full cosine value from there
    assert train.cosine_lr(0.1, 10, 100) == pytest.approx(
        0.1 * 0.5 * (1 + np.cos(np.pi * 0.1)), rel=1e-6
    )
    assert train.cosine_lr(0.1, 100, 100) == pytest.approx(0.0, abs=1e-9)
    # warmup can be disabled (joint phase)
    assert train.cosine_lr(0.1, 0, 100, warmup_frac=0.0) == pytest.approx(0.1)


def test_quant_noise_disabled_at_zero_bits():
    import jax

    f = jnp.ones((2, 4, 4, 3))
    out = train._quant_noise(jax.random.PRNGKey(0), f, 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(f))


def test_alpha_sigmoid_matches_losses():
    assert float(losses.alpha_of(jnp.asarray(0.0), T=4.0)) == 0.5


def test_baseline_training_smoke():
    cfg = train.AgileConfig(dataset="svhns", batch_size=32)
    x, y = data.load("svhns", "train")
    x, y = x[:256], y[:256]
    dc, hist = train.train_deepcod(cfg, x, y, steps=4)
    assert len(hist) == 4 and np.isfinite(hist).all()
    sp, hist = train.train_spinn(cfg, x, y, steps=4)
    assert np.isfinite(hist).all()
    mc, hist = train.train_mcunet(cfg, x, y, steps=4)
    assert np.isfinite(hist).all()


def test_natural_skewness_of_untrained_extractor_is_moderate():
    """Fig 4's premise: without manipulation, importance is not very skewed."""
    import jax

    cfg = train.AgileConfig(dataset="svhns", pre_steps=30, batch_size=32)
    x, y = data.load("svhns", "train")
    ext, ref, _ = train.train_reference(cfg, x, y)
    feats = models.extractor_apply(ext, jnp.asarray(x[:64]))
    imp = xai.ig_importance(ref, feats, jnp.asarray(y[:64]), steps=2)
    skew = np.asarray(xai.natural_skewness(imp, 5))
    # top-5 of 24 channels hold well under 100% of the mass before training
    assert skew.mean() < 0.95
