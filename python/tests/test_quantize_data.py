"""Quantizer + synthetic-dataset tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev-dep: skip, don't error
from hypothesis import given, settings, strategies as st

from compile import data, quantize

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


# ---- quantizer ----


@given(bits=st.integers(1, 6), seed=st.integers(0, 1000))
def test_codebook_size_and_sorted(bits, seed):
    rng = np.random.default_rng(seed)
    vals = np.abs(rng.normal(size=5000)).astype(np.float32)
    cb = quantize.fit_codebook(vals, bits)
    assert cb.shape == (1 << bits,)
    assert (np.diff(cb) >= 0).all()


@given(bits=st.integers(2, 6), seed=st.integers(0, 1000))
def test_roundtrip_error_bounded_by_range(bits, seed):
    rng = np.random.default_rng(seed)
    vals = rng.uniform(0, 4, size=4000).astype(np.float32)
    cb = quantize.fit_codebook(vals, bits)
    err = np.abs(quantize.roundtrip(vals, cb) - vals)
    # nearest-codeword error can never exceed the largest half-gap
    max_gap = np.max(np.diff(cb)) if len(cb) > 1 else np.ptp(vals)
    assert err.max() <= max_gap / 2 + max(vals.max() - cb[-1], cb[0] - vals.min(), 0) + 1e-6


def test_more_bits_less_mse():
    rng = np.random.default_rng(0)
    vals = np.abs(rng.normal(size=8000)).astype(np.float32)
    mses = [quantize.quantization_mse(vals, quantize.fit_codebook(vals, b))
            for b in (1, 2, 4, 6)]
    assert mses == sorted(mses, reverse=True)


def test_quantize_indices_within_codebook():
    rng = np.random.default_rng(1)
    vals = rng.normal(size=1000).astype(np.float32)
    cb = quantize.fit_codebook(vals, 3)
    idx = quantize.quantize(vals, cb)
    assert idx.max() < 8 and idx.min() >= 0


def test_entropy_at_most_bits():
    rng = np.random.default_rng(2)
    vals = np.abs(rng.normal(size=4000)).astype(np.float32)
    for b in (2, 4):
        cb = quantize.fit_codebook(vals, b)
        h = quantize.code_entropy_bits(quantize.quantize(vals, cb))
        assert 0.0 < h <= b + 1e-6


def test_zero_skewed_values_entropy_below_bits():
    """Post-ReLU features are zero-heavy -> entropy well below bit width,
    which is exactly why LZW wins (paper §6)."""
    rng = np.random.default_rng(3)
    vals = rng.normal(size=8000).astype(np.float32)
    vals[vals < 0.8] = 0.0  # ~80% zeros
    cb = quantize.fit_codebook(vals, 4)
    h = quantize.code_entropy_bits(quantize.quantize(vals, cb))
    assert h < 2.5


# ---- datasets ----


def test_dataset_shapes_and_ranges():
    for name, spec in data.SPECS.items():
        x, y = data.load(name, "test")
        assert x.shape == (spec.test_size, data.IMG, data.IMG, 3)
        assert x.dtype == np.float32 and y.dtype == np.int32
        assert 0.0 <= x.min() and x.max() <= 1.0
        assert y.min() >= 0 and y.max() < spec.num_classes


def test_dataset_deterministic():
    x1, y1 = data.load("svhns", "test")
    x2, y2 = data.load("svhns", "test")
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_train_test_disjoint_noise():
    xtr, _ = data.load("svhns", "train")
    xte, _ = data.load("svhns", "test")
    assert not np.array_equal(xtr[: len(xte)], xte)


def test_batches_shapes_and_coverage():
    x = np.arange(40, dtype=np.float32).reshape(10, 2, 2, 1)
    y = np.arange(10, dtype=np.int32)
    seen = []
    for xb, yb in data.batches(x, y, 4, seed=0, epochs=1):
        assert xb.shape == (4, 2, 2, 1)
        seen.extend(yb.tolist())
    assert len(seen) == 8  # drops ragged tail
    assert len(set(seen)) == 8  # no duplicates within an epoch
