"""Unit + property tests for the loss functions and XAI attribution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev-dep: skip, don't error
from hypothesis import given, settings, strategies as st

from compile import losses, models, xai

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _norm_imp(rng, b, c):
    imp = rng.uniform(0.01, 1.0, size=(b, c)).astype(np.float32)
    return jnp.asarray(imp / imp.sum(axis=1, keepdims=True))


# ---- disorder loss (Eq. 1) ----


def test_disorder_loss_zero_when_ordered():
    imp = jnp.asarray([[0.4, 0.3, 0.2, 0.07, 0.03]])
    assert float(losses.disorder_loss(imp, 2)) == 0.0


def test_disorder_loss_positive_on_violation():
    imp = jnp.asarray([[0.1, 0.2, 0.5, 0.1, 0.1]])  # channel 2 outranks 0,1
    assert float(losses.disorder_loss(imp, 2)) > 0.0


@given(seed=st.integers(0, 10_000), k=st.integers(1, 6))
def test_disorder_loss_nonnegative(seed, k):
    imp = _norm_imp(np.random.default_rng(seed), 4, 8)
    assert float(losses.disorder_loss(imp, k)) >= 0.0


def test_disorder_loss_mask_discards_wrong_reference_samples():
    imp = jnp.asarray([[0.1, 0.2, 0.5, 0.1, 0.1], [0.5, 0.3, 0.1, 0.05, 0.05]])
    mask = jnp.asarray([0.0, 1.0])  # first sample: reference was wrong
    assert float(losses.disorder_loss(imp, 2, sample_mask=mask)) == 0.0


# ---- skewness loss (Eq. 2) ----


def test_skewness_loss_zero_when_met():
    imp = jnp.asarray([[0.5, 0.4, 0.05, 0.03, 0.02]])
    assert float(losses.skewness_loss(imp, 2, 0.8)) == 0.0


def test_skewness_loss_measures_deficit():
    imp = jnp.asarray([[0.3, 0.3, 0.2, 0.1, 0.1]])
    np.testing.assert_allclose(float(losses.skewness_loss(imp, 2, 0.8)), 0.2, rtol=1e-5)


@given(seed=st.integers(0, 10_000), k=st.integers(1, 6), rho=st.floats(0.0, 1.0))
def test_skewness_loss_bounded(seed, k, rho):
    imp = _norm_imp(np.random.default_rng(seed), 4, 8)
    v = float(losses.skewness_loss(imp, k, rho))
    assert 0.0 <= v <= rho + 1e-6


# ---- alpha combiner (§3.3) ----


def test_alpha_monotone_in_w_and_saturates_slower_with_high_T():
    w = jnp.asarray(4.0)
    assert float(losses.alpha_of(w, T=2.0)) > float(losses.alpha_of(w, T=8.0)) > 0.5
    assert float(losses.alpha_of(jnp.asarray(0.0), T=6.0)) == 0.5


def test_combine_predictions_endpoints():
    lo, hi = jnp.asarray([[1.0, 0.0]]), jnp.asarray([[0.0, 1.0]])
    np.testing.assert_allclose(np.asarray(losses.combine_predictions(lo, hi, 1.0)),
                               np.asarray(lo))
    np.testing.assert_allclose(np.asarray(losses.combine_predictions(lo, hi, 0.0)),
                               np.asarray(hi))


def test_combined_loss_lambda_weighting():
    v = float(losses.combined_loss(1.0, 0.5, 0.5, lam=0.3))
    np.testing.assert_allclose(v, 0.3 * 1.0 + 0.7 * 1.0, rtol=1e-6)


# ---- XAI attribution ----


def _tiny_ref(nc=4):
    return models.init_reference(jax.random.PRNGKey(0), 6, nc, width=8)


@given(seed=st.integers(0, 1000))
def test_ig_importance_is_distribution(seed):
    rng = np.random.default_rng(seed)
    ref = _tiny_ref()
    feats = jnp.asarray(rng.normal(size=(3, 8, 8, 6)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 4, size=3))
    imp = np.asarray(xai.ig_importance(ref, feats, labels, steps=4))
    assert imp.shape == (3, 6)
    assert (imp >= 0).all()
    np.testing.assert_allclose(imp.sum(axis=1), np.ones(3), rtol=1e-4)


def test_gs_importance_is_distribution():
    rng = np.random.default_rng(7)
    ref = _tiny_ref()
    feats = jnp.asarray(rng.normal(size=(2, 8, 8, 6)).astype(np.float32))
    labels = jnp.asarray([0, 1])
    imp = np.asarray(xai.gs_importance(ref, feats, labels))
    np.testing.assert_allclose(imp.sum(axis=1), np.ones(2), rtol=1e-4)


def test_ig_zero_feature_channel_gets_zero_importance():
    """IG with zero baseline: a channel identically 0 has (x - x0) = 0."""
    rng = np.random.default_rng(3)
    ref = _tiny_ref()
    feats = rng.normal(size=(2, 8, 8, 6)).astype(np.float32)
    feats[..., 2] = 0.0
    imp = np.asarray(xai.ig_importance(ref, jnp.asarray(feats), jnp.asarray([0, 1]), steps=4))
    np.testing.assert_allclose(imp[:, 2], 0.0, atol=1e-7)


def test_ig_differentiable_wrt_features():
    ref = _tiny_ref()
    rng = np.random.default_rng(5)
    feats = jnp.asarray(rng.normal(size=(2, 8, 8, 6)).astype(np.float32))
    labels = jnp.asarray([0, 1])

    def loss(f):
        imp = xai.ig_importance(ref, f, labels, steps=2)
        return jnp.sum(imp[:, :2])  # the skewness objective shape

    g = jax.grad(loss)(feats)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0.0


# ---- skewness metrics ----


def test_natural_vs_achieved_skewness():
    imp = jnp.asarray([[0.05, 0.05, 0.5, 0.3, 0.1]])
    # top-2 sorted mass = 0.8; first-2-position mass = 0.1
    np.testing.assert_allclose(float(xai.natural_skewness(imp, 2)[0]), 0.8, rtol=1e-5)
    np.testing.assert_allclose(float(xai.achieved_skewness(imp, 2)[0]), 0.1, rtol=1e-5)


def test_disorder_rate():
    imp = jnp.asarray([[0.4, 0.3, 0.2, 0.1], [0.1, 0.2, 0.4, 0.3]])
    np.testing.assert_allclose(float(xai.disorder_rate(imp, 2)), 0.5)
